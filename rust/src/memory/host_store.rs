//! Host-side ("CPU memory") store of all expert weights, quantized.
//!
//! The offloading premise of the paper: every expert lives here; only a
//! bounded set is resident in [`super::device_cache::DeviceCache`] at a
//! time. The store is immutable after construction and shared by reference
//! with the transfer engine's comm thread.
//!
//! A store is either **local** (every expert quantized up front from the
//! weights file — the historical shape) or **remote**
//! ([`HostStore::remote`]): experts live on an artifact server
//! (`crate::net`, docs/remote-store.md) and are fetched lazily on first
//! use, then pinned in a host-side slot so every later read — tile decode,
//! re-transfer, upgrade — is local and bit-identical. The fetch itself is
//! abstracted behind [`ExpertFetcher`] so this module never depends on a
//! transport; failures surface through [`HostStore::try_fetch`] as
//! retryable errors the transfer engine's fault pump handles like a
//! dropped job.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::memory::quant::{QuantKind, QuantTensor};
use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::model::ExpertId;
use crate::tensor::Tensor;

/// One expert's three matrices, quantized for storage/transfer.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantExpert {
    pub w1: QuantTensor, // [d, f] flattened
    pub w3: QuantTensor, // [d, f]
    pub w2: QuantTensor, // [f, d]
    pub d: usize,
    pub f: usize,
}

impl QuantExpert {
    pub fn size_bytes(&self) -> usize {
        self.w1.size_bytes() + self.w3.size_bytes() + self.w2.size_bytes()
    }
}

/// One expert's dequantized, compute-ready f32 weights.
#[derive(Clone, Debug)]
pub struct ExpertF32 {
    pub w1: Tensor, // [d, f]
    pub w3: Tensor, // [d, f]
    pub w2: Tensor, // [f, d]
}

/// Where [`HostStore::try_fetch`] found the bytes: already host-resident
/// (local build, or a remote expert fetched earlier) vs. pulled over the
/// wire by *this* call. The transfer engine folds this into its
/// `local_bytes`/`remote_bytes` source counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FetchSource {
    Local,
    Remote,
}

/// Transport hook for remote-backed stores: resolve one expert's verified,
/// decoded weights. Implementations (`crate::net::remote`) own retries,
/// checksum verification and reconnects; an `Err` here means the expert is
/// *currently* unavailable — the caller treats it as a retryable fault,
/// not a corrupt store.
pub trait ExpertFetcher: Send + Sync {
    fn fetch(&self, id: ExpertId) -> std::result::Result<QuantExpert, String>;

    /// Resolve a batch of experts in one shot. The default loops over
    /// [`ExpertFetcher::fetch`]; transports with a batched wire op
    /// (`GET_RANGES`, docs/remote-store.md) override this to fetch the
    /// whole set in a single round trip. Results are positional: `out[i]`
    /// decodes `ids[i]`. An `Err` is retryable and leaves no partial
    /// state the caller must unwind — per-id fetches still work.
    fn fetch_many(&self, ids: &[ExpertId]) -> std::result::Result<Vec<QuantExpert>, String> {
        ids.iter().map(|&id| self.fetch(id)).collect()
    }
}

/// Remote-fetch counters shared between a remote-backed store and its
/// transport (`SourceSnapshot` on the stats surface). All monotonic.
#[derive(Default)]
pub struct FetchCounters {
    /// Experts pulled over the wire (first-touch fetches that succeeded).
    pub fetches: std::sync::atomic::AtomicU64,
    /// Encoded artifact bytes those fetches moved.
    pub fetched_bytes: std::sync::atomic::AtomicU64,
    /// Wall-clock nanoseconds spent inside fetches (success or not).
    pub fetch_ns: std::sync::atomic::AtomicU64,
    /// In-transport retry attempts (before the engine's own fault ladder).
    pub retries: std::sync::atomic::AtomicU64,
    /// Responses rejected by chunk/manifest checksum verification.
    pub checksum_failures: std::sync::atomic::AtomicU64,
    /// Connections re-established after a loss.
    pub reconnects: std::sync::atomic::AtomicU64,
    /// Multi-expert round trips (`GET_RANGES`/[`ExpertFetcher::fetch_many`])
    /// that replaced what would otherwise be one fetch per expert.
    pub batched_fetches: std::sync::atomic::AtomicU64,
    /// Per-round-trip fetch latency distribution (seconds; lock-free, so
    /// the transport records through the shared handle).
    pub fetch_hist: crate::util::stats::LogHistogram,
}

enum Backing {
    Local(HashMap<ExpertId, QuantExpert>),
    Remote {
        /// Lazily filled host pins, indexed `layer * n_experts + expert`.
        /// `OnceLock` gives stable `&QuantExpert` borrows for the whole
        /// store lifetime, matching the local HashMap's reference shape.
        slots: Vec<OnceLock<QuantExpert>>,
        /// Per-expert wire bytes from the manifest, same indexing —
        /// metadata reads (gauge charges, cache planning) must never
        /// trigger a network fetch.
        sizes: Vec<usize>,
        fetcher: Arc<dyn ExpertFetcher>,
        counters: Arc<FetchCounters>,
    },
}

pub struct HostStore {
    backing: Backing,
    pub kind: QuantKind,
    pub n_layers: usize,
    pub n_experts: usize,
    /// f32 expert size of this model — the platform calibration input.
    pub expert_bytes_f32: usize,
}

impl HostStore {
    /// Quantize every expert in `weights` into the store.
    pub fn build(cfg: &ModelConfig, weights: &Weights, kind: QuantKind) -> Result<HostStore> {
        let mut experts = HashMap::new();
        for l in 0..cfg.n_layers {
            for e in 0..cfg.n_experts {
                let (w1, w3, w2) = weights.expert(l, e)?;
                if w1.dims != vec![cfg.d_model, cfg.d_ff] || w2.dims != vec![cfg.d_ff, cfg.d_model]
                {
                    bail!("expert ({l},{e}) has unexpected dims {:?}/{:?}", w1.dims, w2.dims);
                }
                experts.insert(
                    (l, e),
                    QuantExpert {
                        w1: QuantTensor::quantize(&w1.data, kind),
                        w3: QuantTensor::quantize(&w3.data, kind),
                        w2: QuantTensor::quantize(&w2.data, kind),
                        d: cfg.d_model,
                        f: cfg.d_ff,
                    },
                );
            }
        }
        Ok(HostStore {
            backing: Backing::Local(experts),
            kind,
            n_layers: cfg.n_layers,
            n_experts: cfg.n_experts,
            expert_bytes_f32: cfg.expert_bytes_f32(),
        })
    }

    /// A store whose experts live on an artifact server and arrive lazily
    /// through `fetcher` on first use. `sizes` are the manifest's per-expert
    /// wire bytes (indexed `layer * n_experts + expert`) so metadata reads
    /// never touch the network; `counters` is shared with the transport so
    /// the stats surface sees one coherent set of remote-fetch numbers.
    pub fn remote(
        kind: QuantKind,
        n_layers: usize,
        n_experts: usize,
        expert_bytes_f32: usize,
        sizes: Vec<usize>,
        fetcher: Arc<dyn ExpertFetcher>,
        counters: Arc<FetchCounters>,
    ) -> Result<HostStore> {
        if sizes.len() != n_layers * n_experts {
            bail!(
                "remote store wants {} per-expert sizes, manifest gave {}",
                n_layers * n_experts,
                sizes.len()
            );
        }
        let slots = (0..sizes.len()).map(|_| OnceLock::new()).collect();
        Ok(HostStore {
            backing: Backing::Remote { slots, sizes, fetcher, counters },
            kind,
            n_layers,
            n_experts,
            expert_bytes_f32,
        })
    }

    pub fn is_remote(&self) -> bool {
        matches!(self.backing, Backing::Remote { .. })
    }

    /// Remote-fetch counters, when this store is remote-backed.
    pub fn fetch_counters(&self) -> Option<&Arc<FetchCounters>> {
        match &self.backing {
            Backing::Local(_) => None,
            Backing::Remote { counters, .. } => Some(counters),
        }
    }

    fn slot_index(&self, id: ExpertId) -> usize {
        assert!(
            id.0 < self.n_layers && id.1 < self.n_experts,
            "expert ({},{}) out of range ({}x{})",
            id.0,
            id.1,
            self.n_layers,
            self.n_experts
        );
        id.0 * self.n_experts + id.1
    }

    /// Resolve one expert, fetching it over the wire first when the store
    /// is remote-backed and the expert has not landed yet. Local stores
    /// (and already-pinned remote experts) answer `FetchSource::Local`;
    /// `FetchSource::Remote` means *this call* moved the bytes. An `Err`
    /// is retryable — the expert stays absent and a later call re-fetches.
    pub fn try_fetch(
        &self,
        id: ExpertId,
    ) -> std::result::Result<(&QuantExpert, FetchSource), String> {
        match &self.backing {
            Backing::Local(experts) => experts
                .get(&id)
                .map(|q| (q, FetchSource::Local))
                .ok_or_else(|| format!("expert ({},{}) not in local store", id.0, id.1)),
            Backing::Remote { slots, fetcher, .. } => {
                let slot = &slots[self.slot_index(id)];
                if let Some(q) = slot.get() {
                    return Ok((q, FetchSource::Local));
                }
                // Fetch outside the OnceLock init so a failure never
                // wedges the slot. A concurrent double-fetch is benign:
                // the encodings are deterministic, so whichever copy wins
                // `set` is bit-identical to the loser's.
                let fetched = fetcher.fetch(id)?;
                let _ = slot.set(fetched);
                Ok((slot.get().expect("slot just initialized"), FetchSource::Remote))
            }
        }
    }

    /// Best-effort batch warm-up: pull every not-yet-pinned expert of
    /// `ids` over the wire in one [`ExpertFetcher::fetch_many`] round trip
    /// and pin the results. A coalesced transfer group calls this before
    /// admitting its members so a cacheless coordinator pays one network
    /// round trip per group instead of one per expert. Failures are
    /// swallowed — each member's own [`HostStore::try_fetch`] retries
    /// through the ordinary fault ladder. Local stores no-op.
    pub fn prefetch(&self, ids: &[ExpertId]) {
        let Backing::Remote { slots, fetcher, counters, .. } = &self.backing else {
            return;
        };
        let missing: Vec<ExpertId> = ids
            .iter()
            .copied()
            .filter(|&id| slots[self.slot_index(id)].get().is_none())
            .collect();
        if missing.len() < 2 {
            // A single miss gains nothing over the per-id path (and an
            // empty batch is a no-op) — let try_fetch handle it.
            return;
        }
        let Ok(fetched) = fetcher.fetch_many(&missing) else { return };
        if fetched.len() != missing.len() {
            return; // malformed batch: fall back to per-id fetches
        }
        // Wire-level counters (fetches, bytes, latency) belong to the
        // fetcher; this one records only that a batch warm-up landed.
        counters.batched_fetches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        for (&id, q) in missing.iter().zip(fetched) {
            // A concurrent per-id fetch may have won the slot; bit-identical
            // encodings make the loser's copy equivalent.
            let _ = slots[self.slot_index(id)].set(q);
        }
    }

    pub fn get(&self, id: ExpertId) -> &QuantExpert {
        match self.try_fetch(id) {
            Ok((q, _)) => q,
            Err(e) => panic!("expert ({},{}) unavailable: {e}", id.0, id.1),
        }
    }

    /// Bytes that cross the simulated link when loading this expert.
    /// Metadata-only for remote stores (manifest sizes) — never fetches.
    pub fn expert_transfer_bytes(&self, id: ExpertId) -> usize {
        match &self.backing {
            Backing::Local(_) => self.get(id).size_bytes(),
            Backing::Remote { sizes, .. } => sizes[self.slot_index(id)],
        }
    }

    /// Full dequantization of one expert (the non-tiled transfer path).
    pub fn dequantize(&self, id: ExpertId) -> ExpertF32 {
        let q = self.get(id);
        ExpertF32 {
            w1: Tensor { dims: vec![q.d, q.f], data: q.w1.dequantize() },
            w3: Tensor { dims: vec![q.d, q.f], data: q.w3.dequantize() },
            w2: Tensor { dims: vec![q.f, q.d], data: q.w2.dequantize() },
        }
    }

    /// Dequantize the f-tile [f_start, f_end) of one expert — the tile-wise
    /// transfer unit of §5/Fig. 6. Row-major layouts make w1/w3 tiles
    /// column slices and the w2 tile a row slice.
    pub fn dequantize_tile(&self, id: ExpertId, f_start: usize, f_end: usize) -> ExpertF32 {
        let q = self.get(id);
        let (d, f) = (q.d, q.f);
        assert!(f_end <= f && f_start < f_end);
        let w = f_end - f_start;
        // w1/w3 are [d, f]: tile is strided. Decode the covering range once,
        // then gather the columns.
        let mut full1 = vec![0f32; d * f];
        let mut full3 = vec![0f32; d * f];
        q.w1.dequantize_range(0, d * f, &mut full1);
        q.w3.dequantize_range(0, d * f, &mut full3);
        let mut t1 = Vec::with_capacity(d * w);
        let mut t3 = Vec::with_capacity(d * w);
        for r in 0..d {
            t1.extend_from_slice(&full1[r * f + f_start..r * f + f_end]);
            t3.extend_from_slice(&full3[r * f + f_start..r * f + f_end]);
        }
        // w2 is [f, d]: tile rows are contiguous.
        let mut full2 = vec![0f32; f * d];
        q.w2.dequantize_range(f_start * d, f_end * d, &mut full2);
        let t2 = full2[f_start * d..f_end * d].to_vec();
        ExpertF32 {
            w1: Tensor { dims: vec![d, w], data: t1 },
            w3: Tensor { dims: vec![d, w], data: t3 },
            w2: Tensor { dims: vec![w, d], data: t2 },
        }
    }

    pub fn total_experts(&self) -> usize {
        self.n_layers * self.n_experts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{micro_config as test_config, synthetic_weights as fake_weights};

    #[test]
    fn build_and_sizes() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 1);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int4).unwrap();
        assert_eq!(hs.total_experts(), cfg.total_experts());
        let b = hs.expert_transfer_bytes((0, 0));
        // int4 ≈ f32/8 plus block params
        assert!(b < cfg.expert_bytes_f32() / 6, "b={b}");
    }

    #[test]
    fn f32_roundtrip_exact() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 2);
        let hs = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
        let d = hs.dequantize((1, 3));
        assert_eq!(&d.w1.data, &w.get("l1.e3.w1").unwrap().data);
        assert_eq!(&d.w2.data, &w.get("l1.e3.w2").unwrap().data);
    }

    #[test]
    fn tiles_reassemble_to_full() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 3);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int8).unwrap();
        let full = hs.dequantize((0, 1));
        let n_tiles = 4;
        let step = cfg.d_ff / n_tiles;
        let mut w1 = vec![0f32; cfg.d_model * cfg.d_ff];
        let mut w2 = vec![0f32; cfg.d_ff * cfg.d_model];
        for t in 0..n_tiles {
            let tile = hs.dequantize_tile((0, 1), t * step, (t + 1) * step);
            for r in 0..cfg.d_model {
                w1[r * cfg.d_ff + t * step..r * cfg.d_ff + (t + 1) * step]
                    .copy_from_slice(&tile.w1.data[r * step..(r + 1) * step]);
            }
            w2[t * step * cfg.d_model..(t + 1) * step * cfg.d_model]
                .copy_from_slice(&tile.w2.data);
        }
        assert_eq!(w1, full.w1.data);
        assert_eq!(w2, full.w2.data);
    }

    #[test]
    fn quant_error_bounded() {
        let cfg = test_config();
        let w = fake_weights(&cfg, 4);
        let hs = HostStore::build(&cfg, &w, QuantKind::Int8).unwrap();
        let deq = hs.dequantize((0, 0));
        let orig = w.get("l0.e0.w1").unwrap();
        let max_err = deq
            .w1
            .data
            .iter()
            .zip(&orig.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 0.002, "max_err={max_err}");
    }

    #[test]
    fn missing_expert_fails_build() {
        let cfg = test_config();
        let mut w = fake_weights(&cfg, 5);
        w.tensors.remove("l0.e0.w1");
        assert!(HostStore::build(&cfg, &w, QuantKind::Int4).is_err());
    }

    use std::sync::atomic::{AtomicU64, Ordering};

    /// Fetcher that serves clones out of a local twin store, optionally
    /// failing the first N calls — the shape `crate::net::remote` fills in
    /// with a real transport.
    struct TwinFetcher {
        twin: Arc<HostStore>,
        fail_first: AtomicU64,
        calls: AtomicU64,
    }

    impl ExpertFetcher for TwinFetcher {
        fn fetch(&self, id: ExpertId) -> std::result::Result<QuantExpert, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if self.fail_first.load(Ordering::Relaxed) > 0 {
                self.fail_first.fetch_sub(1, Ordering::Relaxed);
                return Err("injected fetch failure".into());
            }
            Ok(self.twin.get(id).clone())
        }
    }

    fn remote_twin(kind: QuantKind, fail_first: u64) -> (HostStore, Arc<TwinFetcher>) {
        let cfg = test_config();
        let w = fake_weights(&cfg, 6);
        let twin = Arc::new(HostStore::build(&cfg, &w, kind).unwrap());
        let sizes: Vec<usize> = (0..cfg.n_layers)
            .flat_map(|l| (0..cfg.n_experts).map(move |e| (l, e)))
            .map(|id| twin.expert_transfer_bytes(id))
            .collect();
        let fetcher = Arc::new(TwinFetcher {
            twin: Arc::clone(&twin),
            fail_first: AtomicU64::new(fail_first),
            calls: AtomicU64::new(0),
        });
        let remote = HostStore::remote(
            kind,
            cfg.n_layers,
            cfg.n_experts,
            cfg.expert_bytes_f32(),
            sizes,
            Arc::clone(&fetcher) as Arc<dyn ExpertFetcher>,
            Arc::new(FetchCounters::default()),
        )
        .unwrap();
        (remote, fetcher)
    }

    #[test]
    fn remote_first_touch_fetches_then_pins() {
        let (remote, fetcher) = remote_twin(QuantKind::Int4, 0);
        // Metadata reads must not touch the fetcher.
        let b = remote.expert_transfer_bytes((0, 1));
        assert!(b > 0);
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 0);
        let (_, src) = remote.try_fetch((0, 1)).unwrap();
        assert_eq!(src, FetchSource::Remote);
        // Second read is host-local and does not re-fetch.
        let (_, src) = remote.try_fetch((0, 1)).unwrap();
        assert_eq!(src, FetchSource::Local);
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn remote_expert_bit_identical_to_twin_every_kind() {
        for kind in [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8, QuantKind::F32] {
            let (remote, fetcher) = remote_twin(kind, 0);
            let id = (1, 2);
            let (got, _) = remote.try_fetch(id).unwrap();
            let want = fetcher.twin.get(id);
            for (g, w) in [(&got.w1, &want.w1), (&got.w3, &want.w3), (&got.w2, &want.w2)] {
                assert_eq!(g.kind, w.kind);
                assert_eq!(g.len, w.len);
                assert_eq!(g.data, w.data);
                assert_eq!(g.scales, w.scales);
                assert_eq!(g.mins, w.mins);
            }
            assert_eq!(remote.expert_transfer_bytes(id), want.size_bytes());
        }
    }

    #[test]
    fn remote_fetch_failure_is_retryable_not_sticky() {
        let (remote, fetcher) = remote_twin(QuantKind::Int8, 1);
        assert!(remote.try_fetch((0, 0)).is_err());
        // The slot was not wedged by the failure: a retry succeeds and the
        // expert is pinned from then on.
        let (_, src) = remote.try_fetch((0, 0)).unwrap();
        assert_eq!(src, FetchSource::Remote);
        let (_, src) = remote.try_fetch((0, 0)).unwrap();
        assert_eq!(src, FetchSource::Local);
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn prefetch_batch_pins_missing_and_skips_pinned() {
        let (remote, fetcher) = remote_twin(QuantKind::Int4, 0);
        // Pin one expert the per-id way first.
        remote.try_fetch((0, 0)).unwrap();
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 1);
        remote.prefetch(&[(0, 0), (0, 1), (0, 2)]);
        // Only the two missing experts were fetched (the default
        // fetch_many loops over fetch), in one logical round trip.
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 3);
        let c = remote.fetch_counters().unwrap();
        assert_eq!(c.batched_fetches.load(Ordering::Relaxed), 1);
        // Wire counters stay with the transport — the twin fetcher tracks
        // nothing, so prefetch must not invent fetches of its own.
        assert_eq!(c.fetches.load(Ordering::Relaxed), 0);
        assert_eq!(remote.try_fetch((0, 1)).unwrap().1, FetchSource::Local);
        assert_eq!(remote.try_fetch((0, 2)).unwrap().1, FetchSource::Local);
        // A single-miss batch is a no-op: the per-id path handles it.
        remote.prefetch(&[(0, 3)]);
        assert_eq!(fetcher.calls.load(Ordering::Relaxed), 3);
        // A failed batch is swallowed and not sticky: the experts stay
        // absent and per-id fetches still land them.
        fetcher.fail_first.store(1, Ordering::Relaxed);
        remote.prefetch(&[(1, 0), (1, 1)]);
        assert_eq!(c.batched_fetches.load(Ordering::Relaxed), 1);
        assert_eq!(remote.try_fetch((1, 0)).unwrap().1, FetchSource::Remote);
    }

    #[test]
    fn remote_rejects_wrong_size_table() {
        let fetcher = {
            let (_, f) = remote_twin(QuantKind::Int4, 0);
            f
        };
        assert!(HostStore::remote(
            QuantKind::Int4,
            2,
            4,
            1024,
            vec![16; 3], // wants 8 entries
            fetcher as Arc<dyn ExpertFetcher>,
            Arc::new(FetchCounters::default()),
        )
        .is_err());
    }
}
