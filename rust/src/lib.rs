//! # AdapMoE — adaptive expert gating & management for MoE inference
//!
//! Reproduction of *AdapMoE: Adaptive Sensitivity-based Expert Gating and
//! Management for Efficient MoE Inference* (Zhong et al., ICCAD '24) as a
//! three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: adaptive gating,
//!   multi-layer prefetching, DP cache allocation, two-stream overlap
//!   scheduling, batching, and the offloading memory hierarchy.
//! * **L2 (`python/compile/model.py`)** — the Mixtral-style MoE decoder,
//!   AOT-lowered per component to HLO text at build time.
//! * **L1 (`python/compile/kernels/expert_ffn.py`)** — the Pallas-tiled
//!   SwiGLU expert kernel embedded in those artifacts.
//!
//! The request path is pure rust: [`runtime`] loads the artifacts onto a
//! PJRT CPU client and [`coordinator::engine`] drives decode steps against
//! the [`memory`] hierarchy. See DESIGN.md for the experiment index and
//! EXPERIMENTS.md for measured results.

pub mod bench_support;
pub mod coordinator;
pub mod memory;
pub mod model;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod testutil;
pub mod util;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
