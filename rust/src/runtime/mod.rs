//! PJRT runtime: loads the AOT-compiled HLO text artifacts and executes
//! them from the serving hot path.
//!
//! One `PjRtClient` (CPU) per process; every artifact listed in
//! `manifest.json` is parsed from HLO *text* (`HloModuleProto::from_text_file`
//! — jax ≥0.5 serialized protos are rejected by xla_extension 0.5.1, text
//! round-trips) and compiled once at startup. After that, Python is out of
//! the picture entirely.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::tensor::Tensor;
use crate::util::json::Json;

pub struct Runtime {
    pub client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load + compile the named artifacts (keys of `manifest.artifacts`).
    pub fn load(dir: &Path, manifest: &Json, names: &[String]) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let arts = manifest
            .get("artifacts")
            .context("manifest missing 'artifacts'")?;
        let mut exes = HashMap::new();
        for name in names {
            let entry = arts
                .get(name)
                .with_context(|| format!("manifest has no artifact '{name}'"))?;
            let file = entry
                .get("path")
                .and_then(|p| p.as_str())
                .with_context(|| format!("artifact '{name}' missing path"))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Runtime { client, exes, dir: dir.to_path_buf() })
    }

    /// Load every artifact in the manifest.
    pub fn load_all(dir: &Path, manifest: &Json) -> Result<Runtime> {
        let arts = manifest
            .get("artifacts")
            .context("manifest missing 'artifacts'")?;
        let names: Vec<String> = match arts {
            Json::Obj(m) => m.keys().cloned().collect(),
            _ => bail!("manifest.artifacts must be an object"),
        };
        Self::load(dir, manifest, &names)
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact; returns the flattened tuple outputs.
    /// (All artifacts are lowered with `return_tuple=True`.)
    ///
    /// Implementation note: this goes through `execute_b` with buffers this
    /// function owns, NOT `PjRtLoadedExecutable::execute` — the crate's
    /// `execute` leaks every input buffer (`xla_rs.cc` `buffer.release()`
    /// with no matching delete; ≈0.5 MB per attention step, OOM within
    /// minutes of decoding). Our owned buffers are dropped (and freed by
    /// PJRT's deferred-deletion machinery) after the call.
    pub fn run(&self, name: &str, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("uploading inputs for '{name}'"))?;
        self.run_b(name, &bufs.iter().collect::<Vec<_>>())
    }

    /// Execute with caller-managed device buffers (persistent weights path).
    pub fn run_b(&self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing '{name}'"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of '{name}'"))?;
        lit.to_tuple().context("decomposing output tuple")
    }

    /// Upload a literal to a device buffer (persistent weights path).
    pub fn to_buffer(&self, l: &Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, l)
            .context("uploading literal")
    }
}

// ---------------------------------------------------------------------------
// Literal <-> host tensor conversions
// ---------------------------------------------------------------------------

/// f32 host tensor -> literal of the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(&t.data)
        .reshape(&dims)
        .context("reshaping f32 literal")
}

/// f32 slice + dims -> literal.
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Literal::vec1(data).reshape(&d).context("reshaping f32 literal")
}

/// i32 slice + dims -> literal.
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
    Literal::vec1(data).reshape(&d).context("reshaping i32 literal")
}

/// literal -> f32 host tensor (shape recovered from the literal).
pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("reading f32 literal")?;
    Tensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_literal_shape() {
        let l = i32_literal(&[1, 2, 3, 4], &[4]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }

    // Execution against real artifacts is covered by rust/tests/integration.rs
    // (requires `make artifacts`).
}
