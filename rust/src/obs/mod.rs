//! Observability: the flight recorder and the unified metrics registry.
//!
//! Two pillars (docs/observability.md):
//!
//! * [`recorder`] — a process-global, off-by-default event journal. Hot
//!   paths call [`instant`]/[`span`] unconditionally; when recording is
//!   disabled each call is a single relaxed atomic load, so the serving
//!   path pays nothing and stays bit-for-bit identical to an
//!   un-instrumented build. Drained events export as Chrome trace-event
//!   JSON ([`chrome_trace`]) viewable in Perfetto.
//! * [`metrics`] — [`metrics::MetricsRegistry`] unifies every counter
//!   family in `ServerStats` plus the log-bucketed latency histograms
//!   into Prometheus-style text exposition, served as `{"cmd":"metrics"}`
//!   and dumped by `--metrics-out`.

pub mod metrics;
pub mod recorder;

pub use recorder::{
    chrome_trace, disable, drain, dropped, enable, enabled, expert_corr, instant, span,
    span_ending_now, Event, Name, Track,
};
