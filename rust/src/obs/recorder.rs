//! Flight recorder: a lock-light, per-thread ring-buffer event journal.
//!
//! Every subsystem on the decode path can emit *instants* (a point event)
//! or *spans* (an interval) onto the thread-local ring it owns. Recording
//! is globally gated by one relaxed atomic — when disabled every record
//! call is a single load-and-return, so a disabled run is bit-for-bit
//! identical to a build without the recorder. When enabled, events land in
//! a per-thread `VecDeque` behind a `Mutex` that only the owning thread
//! and `drain()` ever touch, so there is no cross-thread contention on the
//! hot path. Rings are bounded: overflow drops the oldest event and bumps
//! a global drop counter rather than blocking or reallocating without
//! bound.
//!
//! `drain()` collects and clears every ring (typically after
//! `TransferEngine::quiesce`), and [`chrome_trace`] renders the result as
//! Chrome trace-event JSON loadable in Perfetto: each [`Track`] becomes a
//! named *process* and each OS thread a row inside it, so spans emitted by
//! one thread always nest cleanly even when many threads share a track.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::json::Json;

fn lock_unpoisoned<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-thread ring capacity (events). Overflow drops the oldest event and
/// increments [`dropped`].
const RING_CAP: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_THREAD: AtomicU64 = AtomicU64::new(1);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static REGISTRY: Mutex<Vec<Arc<Mutex<VecDeque<Event>>>>> = Mutex::new(Vec::new());

thread_local! {
    static HANDLE: RefCell<Option<ThreadRing>> = const { RefCell::new(None) };
}

struct ThreadRing {
    thread: u64,
    ring: Arc<Mutex<VecDeque<Event>>>,
}

/// Which timeline row family an event belongs to. Tracks render as named
/// Perfetto processes (see [`chrome_trace`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Track {
    /// The decode loop itself (phases, gating, steps, upgrades).
    Decode,
    /// Serving-layer events.
    Server,
    /// Remote expert-store fetches.
    Remote,
    /// One comm lane (index = lane id).
    Lane(usize),
    /// One device shard (index = device id).
    Device(usize),
    /// One precision tier (index = `QuantKind::tier_index`).
    Tier(usize),
}

impl Track {
    /// Stable numeric id for trace export (used as the Chrome `pid`).
    pub fn tid(self) -> u64 {
        match self {
            Track::Decode => 0,
            Track::Server => 1,
            Track::Remote => 2,
            Track::Lane(i) => 10 + i as u64,
            Track::Device(d) => 100 + d as u64,
            Track::Tier(t) => 200 + t as u64,
        }
    }
}

/// Event taxonomy. The transfer lifecycle is
/// `Enqueue → Admit → Wire → Complete` with `Retry`/`Failover`/`Fault`
/// branching off the fault pump; see docs/observability.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Name {
    /// A decode-step phase span (reuses [`crate::coordinator::trace::Phase`]).
    Phase(crate::coordinator::trace::Phase),
    /// Transfer request entered a lane queue.
    Enqueue,
    /// Lane admitted the job (dequeued for service).
    Admit,
    /// Wire time for one tile (modeled link occupancy).
    Wire,
    /// Transfer finished and its results were published.
    Complete,
    /// Fault pump reissued a job on the same lane.
    Retry,
    /// Fault pump moved a job to a healthy lane.
    Failover,
    /// Transfer failed permanently (or an expert was dropped from a plan).
    Fault,
    /// Expert inserted into a device cache.
    CacheInsert,
    /// Expert evicted from a device cache.
    CacheEvict,
    /// Served from a resident copy below the preferred tier.
    CacheDegrade,
    /// Adaptive gating decision for one layer (arg = experts needed).
    GateDecision,
    /// Precision upgrade issued or completed.
    Upgrade,
    /// One remote store fetch round-trip.
    RemoteFetch,
    /// One whole decode step.
    DecodeStep,
}

impl Name {
    pub fn as_str(self) -> &'static str {
        match self {
            Name::Phase(p) => crate::coordinator::trace::Phase::NAMES[p as usize],
            Name::Enqueue => "enqueue",
            Name::Admit => "admit",
            Name::Wire => "wire",
            Name::Complete => "complete",
            Name::Retry => "retry",
            Name::Failover => "failover",
            Name::Fault => "fault",
            Name::CacheInsert => "cache_insert",
            Name::CacheEvict => "cache_evict",
            Name::CacheDegrade => "cache_degrade",
            Name::GateDecision => "gate_decision",
            Name::Upgrade => "upgrade",
            Name::RemoteFetch => "remote_fetch",
            Name::DecodeStep => "decode_step",
        }
    }
}

/// One recorded event. `dur_ns == 0` marks an instant; anything else is a
/// span that *ended* at `ts_ns + dur_ns`. `id` correlates related events
/// (e.g. all lifecycle events of one expert transfer, see
/// [`expert_corr`]); `arg` is a free payload (bytes, counts).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub track: Track,
    pub name: Name,
    pub id: u64,
    pub arg: u64,
    pub thread: u64,
}

/// Correlation id for an expert's transfer lifecycle.
pub fn expert_corr(id: (usize, usize)) -> u64 {
    ((id.0 as u64) << 32) | id.1 as u64
}

/// Whether recording is currently on.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on (idempotent). Pins the monotonic epoch on first call.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn recording off. Already-buffered events stay until [`drain`].
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Events dropped to ring overflow since process start.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn push(ev: Event) {
    HANDLE.with(|h| {
        let mut slot = h.borrow_mut();
        let tr = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(VecDeque::new()));
            lock_unpoisoned(&REGISTRY).push(Arc::clone(&ring));
            ThreadRing { thread: NEXT_THREAD.fetch_add(1, Ordering::Relaxed), ring }
        });
        let mut ring = lock_unpoisoned(&tr.ring);
        if ring.len() >= RING_CAP {
            ring.pop_front();
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(Event { thread: tr.thread, ..ev });
    });
}

/// Record a point event. No-op (one relaxed load) when disabled.
pub fn instant(track: Track, name: Name, id: u64, arg: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    push(Event { ts_ns: now_ns(), dur_ns: 0, track, name, id, arg, thread: 0 });
}

/// Record a span that started at `start` and ends now.
pub fn span(track: Track, name: Name, id: u64, start: Instant) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let dur_ns = start.elapsed().as_nanos() as u64;
    let ts_ns = now_ns().saturating_sub(dur_ns);
    push(Event { ts_ns, dur_ns: dur_ns.max(1), track, name, id, arg: 0, thread: 0 });
}

/// Record a span of known duration that ends now (for callers that already
/// measured elapsed time, e.g. `TraceCollector::record_phase`).
pub fn span_ending_now(track: Track, name: Name, dur_ns: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let ts_ns = now_ns().saturating_sub(dur_ns);
    push(Event { ts_ns, dur_ns: dur_ns.max(1), track, name, id: 0, arg: 0, thread: 0 });
}

/// Collect and clear every thread's ring, sorted by start time. Call after
/// quiesce so in-flight emitters have gone idle.
pub fn drain() -> Vec<Event> {
    let rings = lock_unpoisoned(&REGISTRY);
    let mut out = Vec::new();
    for ring in rings.iter() {
        out.extend(lock_unpoisoned(ring).drain(..));
    }
    drop(rings);
    out.sort_by_key(|e| (e.ts_ns, e.track.tid(), e.thread));
    out
}

/// Render drained events as Chrome trace-event JSON (Perfetto-loadable).
///
/// Tracks map to *processes* (`pid = Track::tid()`) with `process_name`
/// metadata, and each recording OS thread to a `tid` inside the track —
/// so one thread's spans always nest within a row, regardless of how many
/// threads share a track. `n_lanes`/`n_devices` force metadata rows for
/// every configured lane/device even if it recorded nothing.
pub fn chrome_trace(events: &[Event], n_lanes: usize, n_devices: usize) -> Json {
    const TIER_NAMES: [&str; 4] = ["int2", "int4", "int8", "f32"];
    let mut out = Vec::new();
    let meta = |pid: u64, name: String| {
        Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("process_name".into())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::Str(name))])),
        ])
    };
    out.push(meta(Track::Decode.tid(), "decode".into()));
    out.push(meta(Track::Server.tid(), "server".into()));
    out.push(meta(Track::Remote.tid(), "remote".into()));
    for i in 0..n_lanes {
        out.push(meta(Track::Lane(i).tid(), format!("lane {i}")));
    }
    for d in 0..n_devices {
        out.push(meta(Track::Device(d).tid(), format!("device {d}")));
    }
    let mut tiers_seen = [false; TIER_NAMES.len()];
    for ev in events {
        if let Track::Tier(t) = ev.track {
            if t < tiers_seen.len() && !tiers_seen[t] {
                tiers_seen[t] = true;
                out.push(meta(Track::Tier(t).tid(), format!("tier {}", TIER_NAMES[t])));
            }
        }
    }
    for ev in events {
        let args = Json::obj(vec![
            ("id", Json::Num(ev.id as f64)),
            ("arg", Json::Num(ev.arg as f64)),
        ]);
        let mut fields = vec![
            ("name", Json::Str(ev.name.as_str().into())),
            ("cat", Json::Str("obs".into())),
            ("pid", Json::Num(ev.track.tid() as f64)),
            ("tid", Json::Num(ev.thread as f64)),
            ("ts", Json::Num(ev.ts_ns as f64 / 1e3)),
            ("args", args),
        ];
        if ev.dur_ns == 0 {
            fields.push(("ph", Json::Str("i".into())));
            fields.push(("s", Json::Str("t".into())));
        } else {
            fields.push(("ph", Json::Str("X".into())));
            fields.push(("dur", Json::Num(ev.dur_ns as f64 / 1e3)));
        }
        out.push(Json::obj(fields));
    }
    Json::obj(vec![("traceEvents", Json::Arr(out))])
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global and other unit tests may run
    // concurrently (instrumented code records whenever the gate is open),
    // so assert on marker ids rather than exact event counts.
    #[test]
    fn disabled_is_silent_and_enabled_records() {
        const MARK: u64 = 0x0b5_0b5_0b5;
        instant(Track::Decode, Name::GateDecision, MARK, 2);
        assert!(
            !drain().iter().any(|e| e.id == MARK),
            "disabled recorder must buffer nothing"
        );

        enable();
        instant(Track::Lane(1), Name::Enqueue, MARK, 128);
        let t0 = Instant::now();
        span(Track::Lane(1), Name::Wire, MARK, t0);
        span_ending_now(Track::Decode, Name::DecodeStep, 1_000);
        disable();
        instant(Track::Decode, Name::GateDecision, MARK + 1, 0);

        let evs = drain();
        assert!(
            !evs.iter().any(|e| e.id == MARK + 1),
            "post-disable instants must not record"
        );
        assert!(evs.iter().any(|e| e.name == Name::Enqueue
            && e.track == Track::Lane(1)
            && e.id == MARK
            && e.arg == 128
            && e.dur_ns == 0));
        assert!(evs
            .iter()
            .any(|e| e.name == Name::Wire && e.id == MARK && e.dur_ns >= 1));
        assert!(evs
            .iter()
            .any(|e| e.name == Name::DecodeStep && e.dur_ns == 1_000));
        assert!(
            !drain().iter().any(|e| e.id == MARK),
            "drain clears the rings"
        );

        let mine: Vec<Event> = evs
            .iter()
            .copied()
            .filter(|e| e.id == MARK || e.name == Name::DecodeStep)
            .collect();
        let json = chrome_trace(&mine, 2, 1).to_string();
        let parsed = Json::parse(&json).expect("chrome trace parses");
        let tev = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        // At least 3 fixed + 2 lane + 1 device metadata rows + 3 events.
        assert!(tev.len() >= 9);
        assert!(json.contains("\"lane 1\""));
        assert!(json.contains("\"device 0\""));
        assert!(json.contains("process_name"));
    }

    #[test]
    fn corr_and_ids_are_stable() {
        assert_eq!(expert_corr((1, 2)), (1u64 << 32) | 2);
        assert_eq!(Track::Lane(3).tid(), 13);
        assert_eq!(Track::Device(2).tid(), 102);
        assert_eq!(Track::Tier(1).tid(), 201);
        assert_eq!(Name::Complete.as_str(), "complete");
        assert_eq!(
            Name::Phase(crate::coordinator::trace::Phase::Attn).as_str(),
            "attn"
        );
    }
}
