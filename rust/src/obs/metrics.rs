//! Unified metrics registry: one place where every counter family the
//! stack maintains (server, lanes, devices, tiers, remote source,
//! sensitivity) plus the log-bucketed latency histograms are collected and
//! rendered as Prometheus-style text exposition.
//!
//! The registry is snapshot-shaped, not live: [`MetricsRegistry::from_server_stats`]
//! builds it from a [`ServerStats`] point-in-time copy, so rendering never
//! races the hot path. Served over the v2 line protocol as
//! `{"cmd":"metrics"}` and dumped by `--metrics-out` (docs/observability.md).

use crate::server::api::ServerStats;
use crate::util::stats::LogHistogram;

enum Data {
    /// (rendered label block like `{lane="0"}` or "", value) samples.
    Samples(Vec<(String, f64)>),
    Hist(LogHistogram),
}

struct Family {
    name: String,
    kind: &'static str,
    help: String,
    data: Data,
}

/// Ordered collection of metric families; insertion order is render order.
#[derive(Default)]
pub struct MetricsRegistry {
    families: Vec<Family>,
}

fn labels(pairs: &[(&str, &str)]) -> String {
    if pairs.is_empty() {
        return String::new();
    }
    let body: Vec<String> = pairs.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    format!("{{{}}}", body.join(","))
}

/// Render a value the way our JSON writer does: integral values without a
/// fractional part, everything else via the shortest f64 repr.
fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn family(&mut self, name: &str, kind: &'static str, help: &str) -> &mut Family {
        if let Some(i) = self.families.iter().position(|f| f.name == name) {
            return &mut self.families[i];
        }
        self.families.push(Family {
            name: name.to_string(),
            kind,
            help: help.to_string(),
            data: Data::Samples(Vec::new()),
        });
        self.families.last_mut().unwrap()
    }

    /// Add one sample to a counter family (created on first use).
    pub fn counter(&mut self, name: &str, help: &str, lbl: &[(&str, &str)], v: f64) {
        let fam = self.family(name, "counter", help);
        if let Data::Samples(s) = &mut fam.data {
            s.push((labels(lbl), v));
        }
    }

    /// Add one sample to a gauge family (created on first use).
    pub fn gauge(&mut self, name: &str, help: &str, lbl: &[(&str, &str)], v: f64) {
        let fam = self.family(name, "gauge", help);
        if let Data::Samples(s) = &mut fam.data {
            s.push((labels(lbl), v));
        }
    }

    /// Register a histogram family from a [`LogHistogram`] snapshot.
    pub fn histogram(&mut self, name: &str, help: &str, h: &LogHistogram) {
        self.families.push(Family {
            name: name.to_string(),
            kind: "histogram",
            help: help.to_string(),
            data: Data::Hist(h.clone()),
        });
    }

    /// Prometheus text exposition: `# HELP` / `# TYPE` headers followed by
    /// one line per sample; histograms render cumulative `_bucket{le=...}`
    /// series (nonzero buckets + `+Inf`) plus `_sum`/`_count`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for fam in &self.families {
            out.push_str(&format!("# HELP {} {}\n", fam.name, fam.help));
            out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind));
            match &fam.data {
                Data::Samples(samples) => {
                    for (lbl, v) in samples {
                        out.push_str(&format!("{}{} {}\n", fam.name, lbl, fmt_val(*v)));
                    }
                }
                Data::Hist(h) => {
                    for (bound, cum) in h.cumulative() {
                        out.push_str(&format!(
                            "{}_bucket{{le=\"{:e}\"}} {}\n",
                            fam.name, bound, cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_bucket{{le=\"+Inf\"}} {}\n",
                        fam.name,
                        h.count()
                    ));
                    out.push_str(&format!("{}_sum {}\n", fam.name, fmt_val(h.sum_seconds())));
                    out.push_str(&format!("{}_count {}\n", fam.name, h.count()));
                }
            }
        }
        out
    }

    /// Build the full registry from a stats snapshot: every counter family
    /// `ServerStats` carries, the latency quantile gauges, and the three
    /// log-bucketed histograms.
    pub fn from_server_stats(s: &ServerStats) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();

        // -- server ----------------------------------------------------------
        r.gauge("adapmoe_requests_queued", "Requests waiting for a slot.", &[], s.queued as f64);
        r.gauge("adapmoe_requests_active", "Requests currently decoding.", &[], s.active as f64);
        r.counter("adapmoe_requests_served_total", "Completions delivered.", &[], s.served as f64);
        r.counter(
            "adapmoe_requests_cancelled_total",
            "Requests cancelled queued or in flight.",
            &[],
            s.cancelled as f64,
        );
        r.counter(
            "adapmoe_requests_shed_total",
            "Requests shed at admission (overload).",
            &[],
            s.shed as f64,
        );
        r.counter(
            "adapmoe_tokens_generated_total",
            "Tokens emitted across all requests.",
            &[],
            s.tokens_generated as f64,
        );
        r.gauge(
            "adapmoe_tokens_per_sec",
            "Engine decode throughput (rows x steps / s).",
            &[],
            s.tokens_per_sec,
        );
        r.gauge("adapmoe_uptime_seconds", "Service uptime.", &[], s.uptime_s);
        for (q, v) in
            [("0.5", s.token_p50_ms), ("0.95", s.token_p95_ms), ("0.99", s.token_p99_ms)]
        {
            r.gauge(
                "adapmoe_token_latency_ms",
                "Per-decode-step latency quantiles (ms).",
                &[("quantile", q)],
                v,
            );
        }
        for (q, v) in [("0.5", s.request_p50_ms), ("0.99", s.request_p99_ms)] {
            r.gauge(
                "adapmoe_request_latency_ms",
                "Completed-request latency quantiles (ms, submit to finish).",
                &[("quantile", q)],
                v,
            );
        }
        r.gauge(
            "adapmoe_queue_wait_ms",
            "Completed-request queue wait quantiles (ms, submit to start).",
            &[("quantile", "0.5")],
            s.queue_p50_ms,
        );
        for (q, v) in [
            ("0.5", s.lane_queue_p50_ms),
            ("0.95", s.lane_queue_p95_ms),
            ("0.99", s.lane_queue_p99_ms),
        ] {
            r.gauge(
                "adapmoe_lane_queue_delay_ms",
                "Arrived-but-unconsumed time quantiles across lanes (ms).",
                &[("quantile", q)],
                v,
            );
        }
        for (q, v) in
            [("0.5", s.fetch_p50_ms), ("0.95", s.fetch_p95_ms), ("0.99", s.fetch_p99_ms)]
        {
            r.gauge(
                "adapmoe_remote_fetch_ms",
                "Remote store fetch round-trip quantiles (ms).",
                &[("quantile", q)],
                v,
            );
        }

        // -- lanes -----------------------------------------------------------
        for l in &s.lanes {
            let lane = l.lane.to_string();
            let lbl: &[(&str, &str)] = &[("lane", &lane)];
            let counters: [(&str, &str, f64); 9] = [
                (
                    "adapmoe_lane_transfers_total",
                    "Transfers completed per lane.",
                    l.transfers as f64,
                ),
                ("adapmoe_lane_bytes_total", "Bytes moved per lane.", l.bytes as f64),
                ("adapmoe_lane_on_demand_total", "On-demand loads per lane.", l.on_demand as f64),
                ("adapmoe_lane_prefetch_total", "Prefetch loads per lane.", l.prefetch as f64),
                ("adapmoe_lane_upgrades_total", "Precision upgrades per lane.", l.upgrades as f64),
                ("adapmoe_lane_busy_ms_total", "Modeled wire occupancy per lane (ms).", l.busy_ms),
                ("adapmoe_lane_retries_total", "Fault-pump retries per lane.", l.retries as f64),
                ("adapmoe_lane_timeouts_total", "Transfer timeouts per lane.", l.timeouts as f64),
                ("adapmoe_lane_failovers_total", "Jobs moved off the lane.", l.failovers as f64),
            ];
            for (name, help, v) in counters {
                r.counter(name, help, lbl, v);
            }
            r.gauge(
                "adapmoe_lane_queued_bytes",
                "Bytes waiting in the lane queue.",
                lbl,
                l.queued_bytes as f64,
            );
            r.gauge(
                "adapmoe_lane_queued_jobs",
                "Jobs waiting in the lane queue.",
                lbl,
                l.queued_jobs as f64,
            );
            r.gauge(
                "adapmoe_lane_health",
                "Lane health state (1 = in this state).",
                &[("lane", &lane), ("state", l.health.name())],
                1.0,
            );
        }

        // -- devices ---------------------------------------------------------
        for d in &s.devices {
            let dev = d.device.to_string();
            let lbl: &[(&str, &str)] = &[("device", &dev)];
            let counters: [(&str, &str, f64); 3] = [
                ("adapmoe_device_hits_total", "Cache hits per device shard.", d.hits as f64),
                ("adapmoe_device_misses_total", "Cache misses per device shard.", d.misses as f64),
                (
                    "adapmoe_device_evictions_total",
                    "Evictions per device shard.",
                    d.evictions as f64,
                ),
            ];
            for (name, help, v) in counters {
                r.counter(name, help, lbl, v);
            }
            let gauges: [(&str, &str, f64); 5] = [
                (
                    "adapmoe_device_resident",
                    "Experts resident per device shard.",
                    d.resident as f64,
                ),
                (
                    "adapmoe_device_capacity",
                    "Expert capacity per device shard.",
                    d.capacity as f64,
                ),
                (
                    "adapmoe_device_queued_bytes",
                    "Bytes queued toward the device.",
                    d.queued_bytes as f64,
                ),
                (
                    "adapmoe_device_resident_bytes",
                    "Resident bytes per device shard.",
                    d.resident_bytes as f64,
                ),
                (
                    "adapmoe_device_capacity_bytes",
                    "Byte capacity per device shard.",
                    d.capacity_bytes as f64,
                ),
            ];
            for (name, help, v) in gauges {
                r.gauge(name, help, lbl, v);
            }
        }

        // -- tiers -----------------------------------------------------------
        for t in &s.tiers {
            let lbl: &[(&str, &str)] = &[("tier", t.kind.name())];
            let counters: [(&str, &str, f64); 3] = [
                (
                    "adapmoe_tier_transfers_total",
                    "Transfers per precision tier.",
                    t.transfers as f64,
                ),
                ("adapmoe_tier_bytes_total", "Bytes moved per precision tier.", t.bytes as f64),
                ("adapmoe_tier_upgrades_total", "Upgrades landing per tier.", t.upgrades as f64),
            ];
            for (name, help, v) in counters {
                r.counter(name, help, lbl, v);
            }
        }

        // -- source (local vs remote store) ----------------------------------
        let source: [(&str, &str, f64); 10] = [
            (
                "adapmoe_source_local_bytes_total",
                "Bytes served from the local store.",
                s.source.local_bytes as f64,
            ),
            (
                "adapmoe_source_remote_bytes_total",
                "Bytes served via the remote store.",
                s.source.remote_bytes as f64,
            ),
            (
                "adapmoe_remote_faults_total",
                "Transfers failed on remote fetch.",
                s.source.remote_faults as f64,
            ),
            (
                "adapmoe_remote_fetches_total",
                "Remote store fetch round-trips.",
                s.source.fetches as f64,
            ),
            (
                "adapmoe_remote_fetched_bytes_total",
                "Bytes fetched from the remote store.",
                s.source.fetched_bytes as f64,
            ),
            (
                "adapmoe_remote_batched_fetches_total",
                "Grouped fetch_many round-trips.",
                s.source.batched_fetches as f64,
            ),
            (
                "adapmoe_remote_fetch_time_ms_total",
                "Cumulative remote fetch time (ms).",
                s.source.fetch_ms,
            ),
            ("adapmoe_remote_retries_total", "Remote fetch retries.", s.source.retries as f64),
            (
                "adapmoe_remote_checksum_failures_total",
                "Remote fetch checksum failures.",
                s.source.checksum_failures as f64,
            ),
            (
                "adapmoe_remote_reconnects_total",
                "Remote store reconnects.",
                s.source.reconnects as f64,
            ),
        ];
        for (name, help, v) in source {
            r.counter(name, help, &[], v);
        }

        // -- sensitivity map -------------------------------------------------
        let sens: [(&str, &str, f64); 5] = [
            (
                "adapmoe_sensitivity_tier_assigns_total",
                "Sensitivity-driven tier assignments.",
                s.sensitivity.tier_assigns as f64,
            ),
            (
                "adapmoe_sensitivity_plans_total",
                "Sensitivity-driven cache plans.",
                s.sensitivity.plans as f64,
            ),
            (
                "adapmoe_sensitivity_evictions_total",
                "Sensitivity-ranked evictions.",
                s.sensitivity.evictions as f64,
            ),
            (
                "adapmoe_sensitivity_prefetches_total",
                "Sensitivity-ranked prefetches.",
                s.sensitivity.prefetches as f64,
            ),
            (
                "adapmoe_sensitivity_upgrades_total",
                "Sensitivity-ranked upgrades.",
                s.sensitivity.upgrades as f64,
            ),
        ];
        for (name, help, v) in sens {
            r.counter(name, help, &[], v);
        }

        // -- latency histograms ----------------------------------------------
        r.histogram(
            "adapmoe_token_latency_seconds",
            "Per-decode-step latency distribution.",
            &s.token_hist,
        );
        r.histogram(
            "adapmoe_lane_queue_delay_seconds",
            "Arrived-but-unconsumed time distribution across lanes.",
            &s.lane_queue_hist,
        );
        r.histogram(
            "adapmoe_remote_fetch_seconds",
            "Remote store fetch round-trip distribution.",
            &s.fetch_hist,
        );
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_counters_gauges_and_labels() {
        let mut r = MetricsRegistry::new();
        r.counter("x_total", "An x.", &[], 3.0);
        r.counter("y_total", "A y.", &[("lane", "0")], 1.0);
        r.counter("y_total", "A y.", &[("lane", "1")], 2.0);
        r.gauge("z", "A z.", &[], 0.5);
        let text = r.render();
        assert!(text.contains("# HELP x_total An x.\n"));
        assert!(text.contains("# TYPE x_total counter\n"));
        assert!(text.contains("\nx_total 3\n"));
        assert!(text.contains("y_total{lane=\"0\"} 1\n"));
        assert!(text.contains("y_total{lane=\"1\"} 2\n"));
        // one header per family even with many samples
        assert_eq!(text.matches("# TYPE y_total counter").count(), 1);
        assert!(text.contains("# TYPE z gauge\n"));
        assert!(text.contains("\nz 0.5\n"));
    }

    #[test]
    fn render_histogram_series() {
        let h = LogHistogram::new();
        h.record(0.001);
        h.record(0.001);
        h.record(0.5);
        let mut r = MetricsRegistry::new();
        r.histogram("lat_seconds", "A latency.", &h);
        let text = r.render();
        assert!(text.contains("# TYPE lat_seconds histogram\n"));
        assert!(text.contains("lat_seconds_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("lat_seconds_count 3\n"));
        assert!(text.contains("lat_seconds_sum "));
        // cumulative: the 1ms bucket line carries count 2
        assert!(text.contains("} 2\n"), "nonzero cumulative bucket rendered:\n{text}");
    }

    #[test]
    fn from_server_stats_covers_every_family() {
        use crate::memory::quant::QuantKind;
        use crate::server::api::{DeviceSnapshot, LaneSnapshot, TierSnapshot};
        let mut s = ServerStats {
            queued: 1,
            active: 2,
            served: 3,
            cancelled: 1,
            shed: 1,
            tokens_generated: 64,
            tokens_per_sec: 10.0,
            token_p50_ms: 1.0,
            token_p95_ms: 2.0,
            token_p99_ms: 3.0,
            lanes: vec![LaneSnapshot { lane: 0, transfers: 5, ..Default::default() }],
            devices: vec![DeviceSnapshot { device: 0, hits: 4, ..Default::default() }],
            tiers: vec![TierSnapshot {
                kind: QuantKind::Int4,
                transfers: 2,
                bytes: 100,
                upgrades: 1,
            }],
            ..Default::default()
        };
        s.source.fetches = 7;
        s.sensitivity.plans = 2;
        s.token_hist.record(0.002);
        s.lane_queue_hist.record(0.0005);
        let text = MetricsRegistry::from_server_stats(&s).render();
        for fam in [
            "adapmoe_requests_queued",
            "adapmoe_requests_active",
            "adapmoe_requests_served_total",
            "adapmoe_requests_cancelled_total",
            "adapmoe_requests_shed_total",
            "adapmoe_tokens_generated_total",
            "adapmoe_tokens_per_sec",
            "adapmoe_uptime_seconds",
            "adapmoe_token_latency_ms",
            "adapmoe_request_latency_ms",
            "adapmoe_queue_wait_ms",
            "adapmoe_lane_queue_delay_ms",
            "adapmoe_remote_fetch_ms",
            "adapmoe_lane_transfers_total",
            "adapmoe_lane_health",
            "adapmoe_device_hits_total",
            "adapmoe_tier_bytes_total",
            "adapmoe_source_remote_bytes_total",
            "adapmoe_remote_fetches_total",
            "adapmoe_sensitivity_plans_total",
            "adapmoe_token_latency_seconds",
            "adapmoe_lane_queue_delay_seconds",
            "adapmoe_remote_fetch_seconds",
        ] {
            assert!(text.contains(&format!("# TYPE {fam} ")), "missing family {fam}:\n{text}");
        }
        assert!(text.contains("adapmoe_tier_bytes_total{tier=\"int4\"} 100\n"));
        assert!(text.contains("adapmoe_lane_health{lane=\"0\",state=\"healthy\"} 1\n"));
        for q in ["0.5", "0.95", "0.99"] {
            assert!(text.contains(&format!("adapmoe_token_latency_ms{{quantile=\"{q}\"}}")));
            assert!(text.contains(&format!("adapmoe_lane_queue_delay_ms{{quantile=\"{q}\"}}")));
        }
    }
}
