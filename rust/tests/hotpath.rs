//! Decode hot-path tests (artifact-free: synthetic weights, host math).
//! Locks down what docs/hot-path.md promises:
//!
//! 1. **Grouped-compute bit-identity** — the expert-major batched SwiGLU
//!    (`expert_ffn_host_grouped`) produces bit-for-bit the row-major
//!    `expert_ffn_host` output, both as a bare kernel and through a
//!    4-lane out-of-order parallel drain against the serial plan-order
//!    baseline.
//! 2. **Coalesced-job conservation** — a plan whose misses ride coalesced
//!    transfer groups still resolves every compute item exactly once
//!    (`consumed + dropped == planned`) with fewer wire jobs than
//!    transfers.
//! 3. **Coalescing transparency** — batching requests into groups never
//!    changes which experts land resident compared to submitting the same
//!    ids one by one (property-tested over random id mixes).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use adapmoe::coordinator::executor::{
    expert_ffn_host, expert_ffn_host_grouped, run_layer_parallel, run_layer_serial,
};
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::transfer::{LaneConfig, LanePolicy, Priority, TransferEngine};
use adapmoe::prop_assert;
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;
use adapmoe::util::rng::Rng;
use adapmoe::util::threadpool::{RowBufferPool, ThreadPool};

fn fixture(
    quant: QuantKind,
    platform: &str,
    scale: f64,
    lanes: LaneConfig,
) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    let store = Arc::new(HostStore::build(&cfg, &w, quant).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::with_lanes(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
        lanes,
    );
    (store, cache, xfer)
}

fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = Rng::new(seed);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// The expert-major kernel is a bit-for-bit twin of the row-major one at
/// every decode batch size, including rows masked out by a zero
/// coefficient (unrouted rows must stay exactly zero).
#[test]
fn grouped_kernel_bits_match_row_major_at_every_batch() {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    let store = HostStore::build(&cfg, &w, QuantKind::F32).unwrap();
    let pool = RowBufferPool::new();
    for (case, &b) in [1usize, 4, 16].iter().enumerate() {
        let (x, mut coef) = inputs(b, cfg.n_experts, 100 + case as u64);
        // Mask a deterministic subset of rows: the gather must skip them.
        for c in coef.iter_mut() {
            for (r, v) in c.iter_mut().enumerate() {
                if (r + case) % 3 == 0 {
                    *v = 0.0;
                }
            }
        }
        for e in 0..cfg.n_experts {
            let wts = store.dequantize((0, e));
            let row_major = expert_ffn_host(&x, &wts, &coef[e]);
            let expert_major = expert_ffn_host_grouped(&x, &wts, &coef[e], &pool);
            assert_eq!(
                row_major.data, expert_major.data,
                "b={b} expert={e}: expert-major bits diverged"
            );
        }
    }
    // Scratch parked between calls — the kernel allocates only on growth.
    assert!(pool.parked() > 0, "grouped kernel must recycle its scratch");
}

/// A 4-lane parallel drain (grouped kernel, skewed wire clocks, arrival-
/// order consumption) reproduces the single-lane serial baseline
/// (row-major kernel, plan-order consumption) bit-for-bit.
#[test]
fn four_lane_out_of_order_drain_matches_serial_bits() {
    let experts: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(16, 8, 9);

    let serial_out = {
        let (_s, cache, xfer) =
            fixture(QuantKind::Int4, "rtx4090", 1.0, LaneConfig::default());
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 6);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let par_out = {
        // Four lanes at wildly different speeds: completions arrive far
        // from plan order, so the canonical reduction is load-bearing.
        let lanes = LaneConfig::new(4, LanePolicy::RoundRobin)
            .with_time_scales(vec![4.0, 0.4, 2.0, 0.1]);
        let (_s, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0, lanes);
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 6, "in-flight prefetches must be joined");
        let pool = ThreadPool::new(3);
        run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        )
    };

    assert_eq!(serial_out.consumed, experts, "serial drains in plan order");
    assert_eq!(
        serial_out.acc.data, par_out.acc.data,
        "expert-major 4-lane drain must reproduce the serial baseline bits"
    );
}

/// A plan whose misses coalesce into per-device group jobs still resolves
/// every compute item exactly once: `consumed + dropped == planned`, every
/// expert lands resident, and the wire carried fewer jobs than experts.
#[test]
fn coalesced_plan_conserves_completions_and_wire_jobs() {
    let (_s, cache, xfer) = fixture(
        QuantKind::Int4,
        "instant",
        0.0,
        LaneConfig::new(4, LanePolicy::RoundRobin),
    );
    let experts: Vec<usize> = (0..4).collect();
    // Empty cache: every compute is a fresh miss, batched by the planner.
    let plan = build_plan(1, &experts, &[], &cache, &xfer);
    assert_eq!(plan.n_pending(), experts.len());
    assert_eq!(plan.on_demand_issued, experts.len() as u64);

    let (x, coef) = inputs(16, 8, 17);
    let pool = ThreadPool::new(3);
    let out = run_layer_parallel(
        &plan,
        &x,
        &coef,
        ScheduleMode::ExpertWise,
        4,
        &cache,
        &xfer,
        &pool,
    );
    assert_eq!(
        out.consumed.len() + out.dropped.len(),
        plan.n_pending(),
        "every planned item must be consumed or dropped exactly once"
    );
    assert!(out.dropped.is_empty(), "fault-free drain drops nothing");
    for &e in &experts {
        assert!(cache.contains((1, e)), "expert {e} must land resident");
    }
    xfer.quiesce().unwrap();
    let transfers = xfer.stats.transfers.load(Ordering::Relaxed);
    let wire_jobs = xfer.stats.wire_jobs.load(Ordering::Relaxed);
    assert_eq!(transfers, experts.len() as u64);
    assert!(
        wire_jobs < transfers,
        "coalescing must put fewer jobs ({wire_jobs}) on the wire than \
         transfers ({transfers})"
    );
    let members = xfer.stats.coalesced_members.load(Ordering::Relaxed);
    let groups = xfer.stats.coalesced_groups.load(Ordering::Relaxed);
    assert!(groups >= 1, "a multi-miss plan must form at least one group");
    // Singles ride the classic path; grouped members plus singleton jobs
    // account for every transfer.
    assert_eq!(members + (wire_jobs - groups), transfers);
}

/// Property: submitting a random id mix one by one and submitting the
/// same mix as coalesced groups land exactly the same experts resident,
/// with identical transfer conservation — coalescing is a wire-shape
/// optimization, never a semantic one.
#[test]
fn prop_coalescing_never_changes_resident_set() {
    prop::check("coalescing-resident-set", 12, |rng| {
        let cfg = micro_config();
        let mut ids: Vec<(usize, usize)> = (0..cfg.n_layers)
            .flat_map(|l| (0..cfg.n_experts).map(move |e| (l, e)))
            .collect();
        rng.shuffle(&mut ids);
        let n = 2 + rng.usize_below(ids.len() - 2);
        let mut picked: Vec<(usize, usize)> = ids[..n].to_vec();
        // Duplicates must join in-flight transfers in both submission
        // shapes, not double-transfer.
        if rng.chance(0.5) {
            picked.push(picked[0]);
        }
        let lanes = 1 + rng.usize_below(4);
        let pri = if rng.chance(0.5) { Priority::Prefetch } else { Priority::OnDemand };

        let mk = || {
            fixture(
                QuantKind::Int4,
                "instant",
                0.0,
                LaneConfig::new(lanes, LanePolicy::RoundRobin),
            )
        };
        let (_s1, cache_single, xfer_single) = mk();
        for &id in &picked {
            xfer_single.request(id, pri);
        }
        xfer_single.quiesce().unwrap();

        let (_s2, cache_group, xfer_group) = mk();
        let handles = xfer_group.request_group_at(&picked, pri, QuantKind::Int4);
        prop_assert!(
            handles.len() == picked.len(),
            "handles must stay positional with the submitted ids"
        );
        xfer_group.quiesce().unwrap();

        for &id in &ids {
            prop_assert!(
                cache_single.contains(id) == cache_group.contains(id),
                "resident set diverged at {id:?}: singletons={} grouped={}",
                cache_single.contains(id),
                cache_group.contains(id)
            );
        }
        // The group submits under one registry lock, so the duplicate id
        // always joins: exactly one transfer per unique expert. The per-id
        // shape can lose that race on the instant wire (the first copy
        // completes before the duplicate is submitted, forcing a second
        // transfer), so it is only bounded below.
        let t_single = xfer_single.stats.transfers.load(Ordering::Relaxed);
        let t_group = xfer_group.stats.transfers.load(Ordering::Relaxed);
        prop_assert!(
            t_group == n as u64,
            "grouped shape must transfer each unique expert once: {t_group} != {n}"
        );
        prop_assert!(
            t_single >= t_group,
            "per-id shape can only add duplicate transfers: {t_single} < {t_group}"
        );
        let w_single = xfer_single.stats.wire_jobs.load(Ordering::Relaxed);
        let w_group = xfer_group.stats.wire_jobs.load(Ordering::Relaxed);
        prop_assert!(
            w_group <= w_single,
            "grouping must never add wire jobs: {w_group} > {w_single}"
        );
        Ok(())
    });
}
