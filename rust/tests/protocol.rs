//! Wire-protocol tests for the v2 line protocol — full TCP round trips
//! (streaming, cancellation, stats, v1 back-compat) against the
//! artifact-free MockBackend, so they run everywhere `cargo test` does.
//! The same protocol against the real engine + artifacts is covered in
//! rust/tests/integration.rs.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use adapmoe::server::api::GenerationRequest;
use adapmoe::server::tcp;
use adapmoe::testutil::MockBackend;
use adapmoe::util::json::Json;

struct TestServer {
    addr: String,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<u64>>,
}

impl TestServer {
    /// Start `tcp::serve` over a MockBackend and wait until it accepts.
    fn start(port: u16, slots: usize, step_delay_ms: u64) -> TestServer {
        let addr = format!("127.0.0.1:{port}");
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let saddr = addr.clone();
        let thread = std::thread::spawn(move || {
            let mut be = MockBackend::new(slots, 1 << 20);
            be.step_delay = Duration::from_millis(step_delay_ms);
            tcp::serve(be, &saddr, sd).expect("serve")
        });
        for _ in 0..200 {
            if TcpStream::connect(&addr).is_ok() {
                return TestServer { addr, shutdown, thread: Some(thread) };
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("server on {addr} never came up");
    }

    fn connect(&self) -> (TcpStream, BufReader<TcpStream>) {
        let stream = TcpStream::connect(&self.addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .expect("read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        (stream, reader)
    }

    fn stop(mut self) -> u64 {
        self.shutdown.store(true, Ordering::SeqCst);
        self.thread.take().expect("running").join().expect("join")
    }
}

fn send(stream: &mut TcpStream, j: &Json) {
    writeln!(stream, "{}", j.to_string()).expect("write");
}

fn recv(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read");
    assert!(!line.is_empty(), "server closed connection");
    Json::parse(line.trim()).expect("response json")
}

fn event_of(j: &Json) -> String {
    j.get("event").and_then(|e| e.as_str()).unwrap_or("?").to_string()
}

#[test]
fn streamed_generation_event_order_and_back_compat() {
    let srv = TestServer::start(17421, 2, 0);

    // v1 back-compat on the same server: bare prompt → single line, no
    // "event" key, mock generates consecutive bytes ("ab" → "cde")
    let (mut s, mut r) = srv.connect();
    send(&mut s, &Json::parse(r#"{"prompt":"ab","max_new":3}"#).unwrap());
    let done = recv(&mut r);
    assert!(done.get("event").is_none(), "v1 shape must not carry 'event'");
    assert_eq!(done.get("text").and_then(|t| t.as_str()), Some("cde"));
    assert_eq!(done.get("finish").and_then(|f| f.as_str()), Some("length"));
    assert!(done.get("total_ms").and_then(|v| v.as_f64()).unwrap() >= 0.0);

    // streamed: Queued → Started → Token* → Done, ids consistent,
    // indices sequential
    let req = GenerationRequest {
        max_new: 3,
        stream: true,
        ..GenerationRequest::new("ab")
    };
    let (mut s, mut r) = srv.connect();
    send(&mut s, &req.to_json());
    let mut events = Vec::new();
    loop {
        let j = recv(&mut r);
        let e = event_of(&j);
        events.push((e.clone(), j));
        if e == "done" || e == "error" || e == "cancelled" {
            break;
        }
    }
    let kinds: Vec<&str> = events.iter().map(|(e, _)| e.as_str()).collect();
    assert_eq!(kinds, vec!["queued", "started", "token", "token", "token", "done"]);
    let id0 = events[0].1.get("id").and_then(|v| v.as_f64()).unwrap();
    assert!(events.iter().all(|(_, j)| j.get("id").and_then(|v| v.as_f64()) == Some(id0)));
    let idxs: Vec<usize> = events
        .iter()
        .filter(|(e, _)| e == "token")
        .map(|(_, j)| j.get("index").and_then(|v| v.as_usize()).unwrap())
        .collect();
    assert_eq!(idxs, vec![0, 1, 2]);
    let (_, done) = events.last().unwrap();
    assert_eq!(done.get("text").and_then(|t| t.as_str()), Some("cde"));

    // stop tokens end generation early with finish = "stop"
    let (mut s, mut r) = srv.connect();
    send(
        &mut s,
        &Json::parse(r#"{"prompt":"ab","max_new":50,"stop":[101]}"#).unwrap(),
    );
    let done = recv(&mut r);
    assert_eq!(done.get("finish").and_then(|f| f.as_str()), Some("stop"));
    assert_eq!(done.get("text").and_then(|t| t.as_str()), Some("cd"));

    let served = srv.stop();
    assert_eq!(served, 3);
}

#[test]
fn cancel_in_flight_from_second_connection() {
    let srv = TestServer::start(17422, 1, 5);

    let req = GenerationRequest {
        max_new: 100_000,
        stream: true,
        ..GenerationRequest::new("a")
    };
    let (mut s, mut r) = srv.connect();
    send(&mut s, &req.to_json());

    // wait for tokens to flow, note the id
    let mut id = None;
    loop {
        let j = recv(&mut r);
        if id.is_none() {
            id = j.get("id").and_then(|v| v.as_f64()).map(|v| v as u64);
        }
        if event_of(&j) == "token" {
            break;
        }
    }
    let id = id.expect("id on stream lines");

    // cancel by id from a *different* connection
    assert!(tcp::client_cancel(&srv.addr, id).unwrap());

    // the stream terminates with a cancelled line (a few in-flight tokens
    // may still arrive first)
    let mut tokens_after = 0;
    loop {
        let j = recv(&mut r);
        match event_of(&j).as_str() {
            "cancelled" => break,
            "token" => {
                tokens_after += 1;
                assert!(tokens_after < 50, "cancel never landed");
            }
            other => panic!("unexpected event {other}"),
        }
    }

    // slot was freed: a fresh request completes, and stats count the cancel
    let (text, _q, _t) = tcp::client_request(&srv.addr, "ab", 2).unwrap();
    assert_eq!(text, "cd");
    let stats = tcp::client_stats(&srv.addr).unwrap();
    assert_eq!(stats.get("cancelled").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(stats.get("served").and_then(|v| v.as_usize()), Some(1));
    srv.stop();
}

#[test]
fn cancel_queued_request_before_start() {
    let srv = TestServer::start(17423, 1, 5);

    // fill the only slot with a long-running request
    let long = GenerationRequest {
        max_new: 500,
        stream: true,
        ..GenerationRequest::new("a")
    };
    let (mut s1, mut r1) = srv.connect();
    send(&mut s1, &long.to_json());
    loop {
        if event_of(&recv(&mut r1)) == "started" {
            break;
        }
    }

    // second request must sit in the queue; cancel it before it starts
    let queued = GenerationRequest {
        max_new: 5,
        stream: true,
        ..GenerationRequest::new("b")
    };
    let (mut s2, mut r2) = srv.connect();
    send(&mut s2, &queued.to_json());
    let q = recv(&mut r2);
    assert_eq!(event_of(&q), "queued");
    let qid = q.get("id").and_then(|v| v.as_f64()).unwrap() as u64;

    let stats = tcp::client_stats(&srv.addr).unwrap();
    assert_eq!(stats.get("queued").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(stats.get("active").and_then(|v| v.as_usize()), Some(1));

    assert!(tcp::client_cancel(&srv.addr, qid).unwrap());
    // cancelled immediately — no started/token lines in between
    assert_eq!(event_of(&recv(&mut r2)), "cancelled");
    // cancelling an unknown/finished id reports false
    assert!(!tcp::client_cancel(&srv.addr, 9999).unwrap());

    // unblock the long request too
    let lid = 0; // first submission on this server
    assert!(tcp::client_cancel(&srv.addr, lid).unwrap());
    srv.stop();
}

#[test]
fn client_disconnect_mid_stream_cancels_generation() {
    let srv = TestServer::start(17426, 1, 5);

    // start a long streamed generation, read until tokens flow…
    let req = GenerationRequest {
        max_new: 100_000,
        stream: true,
        ..GenerationRequest::new("a")
    };
    let (mut s, mut r) = srv.connect();
    send(&mut s, &req.to_json());
    loop {
        if event_of(&recv(&mut r)) == "token" {
            break;
        }
    }
    // …then vanish without cancelling: the server's liveness probe must
    // notice and cancel the request so the slot frees up
    drop(r);
    drop(s);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let stats = tcp::client_stats(&srv.addr).unwrap();
        if stats.get("cancelled").and_then(|v| v.as_usize()) == Some(1) {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "disconnect never cancelled the request: {stats:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }

    // the freed slot serves a fresh request to completion
    let (text, _q, _t) = tcp::client_request(&srv.addr, "ab", 2).unwrap();
    assert_eq!(text, "cd");
    let served = srv.stop();
    assert_eq!(served, 1);
}

#[test]
fn stats_round_trip_is_nonempty_and_counts() {
    let srv = TestServer::start(17424, 2, 0);

    for _ in 0..2 {
        let (text, _q, _t) = tcp::client_request(&srv.addr, "ab", 4).unwrap();
        assert_eq!(text, "cdef");
    }
    let stats = tcp::client_stats(&srv.addr).unwrap();
    assert_eq!(stats.get("served").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(stats.get("tokens_generated").and_then(|v| v.as_usize()), Some(8));
    assert_eq!(stats.get("queued").and_then(|v| v.as_usize()), Some(0));
    assert!(stats.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert!(stats.get("request_p50_ms").and_then(|v| v.as_f64()).unwrap() >= 0.0);
    assert!(stats.get("uptime_s").and_then(|v| v.as_f64()).unwrap() >= 0.0);
    // per-lane, per-device and per-tier arrays always ride the wire; the
    // mock backend has no transfer engine or cache shards, so all are
    // empty (non-empty device/tier entries are round-tripped in
    // server::api tests)
    assert_eq!(
        stats.get("lanes").and_then(|l| l.as_arr()).map(|a| a.len()),
        Some(0),
        "lanes array must round-trip"
    );
    assert_eq!(
        stats.get("devices").and_then(|d| d.as_arr()).map(|a| a.len()),
        Some(0),
        "devices array must round-trip"
    );
    assert_eq!(
        stats.get("tiers").and_then(|t| t.as_arr()).map(|a| a.len()),
        Some(0),
        "tiers array must round-trip"
    );
    // the sensitivity block rides the wire; the mock backend reports
    // fixed nonzero counters so a dropped field fails here
    let sens = stats.get("sensitivity").expect("sensitivity object must round-trip");
    assert_eq!(sens.get("tier_assigns").and_then(|v| v.as_usize()), Some(5));
    assert_eq!(sens.get("plans").and_then(|v| v.as_usize()), Some(4));
    assert_eq!(sens.get("evictions").and_then(|v| v.as_usize()), Some(3));
    assert_eq!(sens.get("prefetches").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(sens.get("upgrades").and_then(|v| v.as_usize()), Some(1));

    // ping + malformed lines on the same connection
    let (mut s, mut r) = srv.connect();
    send(&mut s, &Json::parse(r#"{"cmd":"ping"}"#).unwrap());
    assert_eq!(recv(&mut r).get("pong").and_then(|b| b.as_bool()), Some(true));
    writeln!(s, "not json").unwrap();
    assert!(recv(&mut r).get("error").is_some());
    send(&mut s, &Json::parse(r#"{"cmd":"nope"}"#).unwrap());
    assert!(recv(&mut r).get("error").is_some());
    // empty prompts are rejected at the wire, not fed to the engine
    send(&mut s, &Json::parse(r#"{"prompt":""}"#).unwrap());
    assert!(recv(&mut r).get("error").is_some());
    // connection still usable after protocol errors
    send(&mut s, &Json::parse(r#"{"cmd":"ping"}"#).unwrap());
    assert_eq!(recv(&mut r).get("pong").and_then(|b| b.as_bool()), Some(true));

    let served = srv.stop();
    assert_eq!(served, 2);
}

#[test]
fn metrics_exposition_and_histograms_ride_the_wire() {
    let srv = TestServer::start(17427, 2, 0);

    let (text, _q, _t) = tcp::client_request(&srv.addr, "ab", 3).unwrap();
    assert_eq!(text, "cde");

    // stats carries the new quantile fields and the raw histogram objects;
    // the mock backend records fixed samples so the counts are known
    let stats = tcp::client_stats(&srv.addr).unwrap();
    for key in [
        "token_p95_ms",
        "lane_queue_p50_ms",
        "lane_queue_p95_ms",
        "lane_queue_p99_ms",
        "fetch_p50_ms",
        "fetch_p95_ms",
        "fetch_p99_ms",
    ] {
        assert!(
            stats.get(key).and_then(|v| v.as_f64()).unwrap() >= 0.0,
            "{key} must ride the wire"
        );
    }
    let th = adapmoe::util::stats::LogHistogram::from_json(
        stats.get("token_hist").expect("token_hist must round-trip"),
    );
    assert_eq!(th.count(), 3);
    let lh = adapmoe::util::stats::LogHistogram::from_json(
        stats.get("lane_queue_hist").expect("lane_queue_hist must round-trip"),
    );
    assert_eq!(lh.count(), 2);
    let fh = adapmoe::util::stats::LogHistogram::from_json(
        stats.get("fetch_hist").expect("fetch_hist must round-trip"),
    );
    assert!(fh.is_empty(), "mock backend records no remote fetches");
    // p95 over the mock's {10µs, 100µs, 1ms} token samples upper-bounds 1ms
    assert!(
        stats.get("token_p95_ms").and_then(|v| v.as_f64()).unwrap() >= 1.0,
        "token p95 must cover the slowest recorded sample"
    );

    // the metrics op answers a Prometheus-style exposition covering every
    // counter family plus quantile series for the recorded histograms
    let text = tcp::client_metrics(&srv.addr).unwrap();
    for needle in [
        "# TYPE adapmoe_requests_served_total counter",
        "adapmoe_requests_served_total 1",
        "adapmoe_tokens_generated_total 3",
        "adapmoe_uptime_seconds",
        "adapmoe_token_latency_ms{quantile=\"0.5\"}",
        "adapmoe_token_latency_ms{quantile=\"0.95\"}",
        "adapmoe_token_latency_ms{quantile=\"0.99\"}",
        "adapmoe_lane_queue_delay_ms{quantile=\"0.95\"}",
        "adapmoe_remote_fetch_ms{quantile=\"0.99\"}",
        "# TYPE adapmoe_token_latency_seconds histogram",
        "adapmoe_token_latency_seconds_count 3",
        "# TYPE adapmoe_lane_queue_delay_seconds histogram",
        "adapmoe_lane_queue_delay_seconds_count 2",
        "adapmoe_sensitivity_tier_assigns_total 5",
    ] {
        assert!(text.contains(needle), "metrics exposition missing {needle:?}:\n{text}");
    }

    srv.stop();
}

#[test]
fn priority_and_sampling_params_ride_the_wire() {
    let srv = TestServer::start(17425, 1, 2);

    // same seed + temperature → identical sampled outputs end to end
    let mk = |seed| GenerationRequest {
        max_new: 6,
        temperature: 0.9,
        top_k: 4,
        seed: Some(seed),
        ..GenerationRequest::new("ab")
    };
    let a = tcp::client_generate(&srv.addr, &mk(7)).unwrap();
    let b = tcp::client_generate(&srv.addr, &mk(7)).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must reproduce");
    assert_eq!(a.tokens.len(), 6);

    // a high-priority request overtakes a low-priority one in the queue:
    // occupy the slot, enqueue low then high, check completion order
    let long = GenerationRequest {
        max_new: 100,
        stream: true,
        ..GenerationRequest::new("a")
    };
    let (mut s0, mut r0) = srv.connect();
    send(&mut s0, &long.to_json());
    loop {
        if event_of(&recv(&mut r0)) == "started" {
            break;
        }
    }
    let spawn_req = |prio: i32| {
        let addr = srv.addr.clone();
        std::thread::spawn(move || {
            let req = GenerationRequest {
                max_new: 2,
                priority: prio,
                ..GenerationRequest::new("ab")
            };
            let done = tcp::client_generate(&addr, &req).unwrap();
            (prio, done.queue_ms)
        })
    };
    let low = spawn_req(-1);
    std::thread::sleep(Duration::from_millis(50)); // low is definitely queued first
    let high = spawn_req(3);
    let (_, low_wait) = low.join().unwrap();
    let (_, high_wait) = high.join().unwrap();
    assert!(
        high_wait < low_wait,
        "high priority waited {high_wait}ms, low {low_wait}ms"
    );
    srv.stop();
}
