//! Observability acceptance tests (artifact-free: synthetic weights,
//! host-math executor). Locks down what `docs/observability.md` promises:
//!
//! 1. **Zero-cost when off** — a 4-lane/2-device out-of-order drain with
//!    the recorder disabled produces bit-identical output to the same
//!    drain with it enabled (and to a disabled re-run): recording never
//!    perturbs logits, only observes them.
//! 2. **Conserved lifecycle** — the enabled run journals a conserved
//!    transfer lifecycle (every `complete` correlates to an `enqueue`)
//!    and exports a Perfetto-loadable Chrome trace with every configured
//!    lane/device as a named track; CI re-validates the emitted file with
//!    `tools/check_trace.py`.
//! 3. **Unified exposition** — the metrics registry renders every counter
//!    family a [`ServerStats`] carries, plus p50/p95/p99 quantile series
//!    for token latency and lane queue delay.
//! 4. **Publish-before-remove** — after `quiesce()` the per-lane counters
//!    account for every transfer and all queue gauges read zero, so a
//!    stats/metrics snapshot taken after quiesce never under-reports.
//!
//! Everything lives in one `#[test]` because the recorder gate is
//! process-global: a second concurrently-running test that moves experts
//! would journal into the same rings and break the conservation counts.

use std::sync::Arc;

use adapmoe::coordinator::executor::run_layer_parallel;
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::{Placement, ShardedCache};
use adapmoe::memory::transfer::{
    LaneConfig, LanePolicy, Priority, SensitivitySnapshot, TransferEngine,
};
use adapmoe::obs;
use adapmoe::obs::metrics::MetricsRegistry;
use adapmoe::server::api::ServerStats;
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::json::Json;
use adapmoe::util::rng::Rng;
use adapmoe::util::stats::LogHistogram;
use adapmoe::util::threadpool::ThreadPool;

const N_LANES: usize = 4;
const N_DEVICES: usize = 2;
const EXPERTS: usize = 8;

fn fixture() -> (Arc<ShardedCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    let store = Arc::new(HostStore::build(&cfg, &w, QuantKind::Int4).unwrap());
    let cache = Arc::new(ShardedCache::new(
        vec![vec![8, 8]; N_DEVICES],
        Placement::ExpertHash,
    ));
    // Skewed per-lane wire clocks scramble completion order across the
    // lane groups, same shape as the devices.rs determinism test.
    let lanes = LaneConfig::new(N_LANES, LanePolicy::RoundRobin)
        .with_time_scales(vec![1.2, 0.9, 0.6, 0.3]);
    let xfer = TransferEngine::with_devices(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset("rtx4090").unwrap(),
        4,
        1.0,
        lanes,
    );
    (cache, xfer)
}

fn inputs() -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = Rng::new(33);
    let b = 4;
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..EXPERTS)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// Prefetch all of layer 0, join the in-flight transfers into a plan and
/// drain it in arrival order. Returns the reduced output bits, the
/// consumption order and the engine (for counter asserts).
fn drain_once() -> (Vec<f32>, Vec<usize>, TransferEngine) {
    let experts: Vec<usize> = (0..EXPERTS).collect();
    let (x, coef) = inputs();
    let (cache, xfer) = fixture();
    for &e in &experts {
        xfer.request((0, e), Priority::Prefetch);
    }
    let plan = build_plan(0, &experts, &[], &cache, &xfer);
    assert_eq!(plan.n_pending(), EXPERTS, "in-flight prefetches must be joined");
    let pool = ThreadPool::new(4);
    let out = run_layer_parallel(
        &plan,
        &x,
        &coef,
        ScheduleMode::ExpertWise,
        4,
        &cache,
        &xfer,
        &pool,
    );
    xfer.quiesce().unwrap();
    (out.acc.data.clone(), out.consumed.clone(), xfer)
}

#[test]
fn recorder_is_invisible_conserved_and_metrics_cover_stats() {
    // -- 1. disabled baseline ------------------------------------------------
    assert!(!obs::enabled());
    let (bits_off, _, _) = drain_once();
    assert!(
        obs::drain().is_empty(),
        "disabled recorder must journal nothing"
    );

    // -- 2. enabled run: same bits, conserved lifecycle ----------------------
    obs::enable();
    let (bits_on, consumed, xfer) = drain_once();
    obs::disable();
    let events = obs::drain();

    assert_eq!(
        bits_off, bits_on,
        "recording must not perturb output bits"
    );
    let (bits_off2, _, _) = drain_once();
    assert_eq!(bits_off2, bits_off, "disabled re-run must reproduce");
    assert_eq!(consumed.len(), EXPERTS);
    assert_ne!(
        consumed,
        (0..EXPERTS).collect::<Vec<_>>(),
        "skewed lane clocks must scramble arrival order"
    );

    let ids = |name: obs::Name| -> Vec<u64> {
        events
            .iter()
            .filter(|e| e.name == name)
            .map(|e| e.id)
            .collect()
    };
    let enqueued = ids(obs::Name::Enqueue);
    let completed = ids(obs::Name::Complete);
    assert_eq!(enqueued.len(), EXPERTS, "one enqueue per requested expert");
    assert_eq!(completed.len(), EXPERTS, "one complete per requested expert");
    for id in &completed {
        assert!(
            enqueued.contains(id),
            "complete {id:#x} without a matching enqueue"
        );
    }
    assert!(
        !ids(obs::Name::Admit).is_empty(),
        "admissions must be journaled"
    );
    assert!(
        events.iter().any(|e| e.name == obs::Name::Wire && e.dur_ns > 0),
        "wire occupancy must be journaled as spans"
    );
    assert!(
        !events.iter().any(|e| e.name == obs::Name::Fault),
        "fault-free drain must journal no faults"
    );
    let lanes_seen: std::collections::HashSet<u64> = events
        .iter()
        .filter(|e| matches!(e.track, obs::Track::Lane(_)))
        .map(|e| e.track.tid())
        .collect();
    assert!(
        lanes_seen.len() >= 2,
        "round-robin must spread events over lanes: {lanes_seen:?}"
    );

    // -- 3. Chrome trace export (CI runs tools/check_trace.py on it) ---------
    let trace = obs::chrome_trace(&events, N_LANES, N_DEVICES);
    std::fs::create_dir_all("target").unwrap();
    std::fs::write("target/obs_trace.json", trace.to_string()).unwrap();
    let parsed = Json::parse(&trace.to_string()).expect("trace is valid json");
    let tev = parsed
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(tev.len() >= 3 + N_LANES + N_DEVICES + events.len());
    let text = trace.to_string();
    for track in ["\"lane 0\"", "\"lane 3\"", "\"device 0\"", "\"device 1\""] {
        assert!(text.contains(track), "trace must name track {track}");
    }

    // -- 4. publish-before-remove: post-quiesce snapshots are complete -------
    let lanes = xfer.lane_snapshots();
    assert_eq!(
        lanes.iter().map(|l| l.transfers).sum::<u64>(),
        EXPERTS as u64,
        "lane counters must account for every transfer after quiesce"
    );
    assert!(
        lanes.iter().all(|l| l.queued_bytes == 0 && l.queued_jobs == 0),
        "lane queue gauges must drain to zero: {lanes:?}"
    );
    assert!(
        xfer.device_snapshots().iter().all(|d| d.queued_bytes == 0),
        "device queue gauges must drain to zero"
    );

    // -- 5. metrics exposition covers every ServerStats family ---------------
    let token_hist = LogHistogram::default();
    for s in [0.0008, 0.0012, 0.0030] {
        token_hist.record(s);
    }
    let lane_queue_hist = LogHistogram::default();
    for s in [0.0001, 0.0004] {
        lane_queue_hist.record(s);
    }
    let stats = ServerStats {
        queued: 1,
        active: 1,
        served: 2,
        cancelled: 1,
        shed: 1,
        tokens_generated: 64,
        tokens_per_sec: 12.5,
        token_p50_ms: 0.8,
        token_p95_ms: token_hist.quantile(0.95) * 1e3,
        token_p99_ms: 3.0,
        request_p50_ms: 5.0,
        request_p99_ms: 9.0,
        queue_p50_ms: 0.5,
        lane_queue_p50_ms: lane_queue_hist.quantile(0.50) * 1e3,
        lane_queue_p95_ms: lane_queue_hist.quantile(0.95) * 1e3,
        lane_queue_p99_ms: lane_queue_hist.quantile(0.99) * 1e3,
        uptime_s: 1.0,
        lanes: xfer.lane_snapshots(),
        devices: xfer.device_snapshots(),
        tiers: xfer.tier_snapshots(),
        source: xfer.source_snapshot(),
        sensitivity: SensitivitySnapshot {
            tier_assigns: 5,
            plans: 4,
            evictions: 3,
            prefetches: 2,
            upgrades: 1,
        },
        token_hist,
        lane_queue_hist,
        ..ServerStats::default()
    };
    let text = MetricsRegistry::from_server_stats(&stats).render();
    for family in [
        "adapmoe_requests_queued",
        "adapmoe_requests_active",
        "adapmoe_requests_served_total",
        "adapmoe_requests_cancelled_total",
        "adapmoe_requests_shed_total",
        "adapmoe_tokens_generated_total",
        "adapmoe_tokens_per_sec",
        "adapmoe_uptime_seconds",
        "adapmoe_token_latency_ms",
        "adapmoe_request_latency_ms",
        "adapmoe_queue_wait_ms",
        "adapmoe_lane_queue_delay_ms",
        "adapmoe_remote_fetch_ms",
        "adapmoe_lane_transfers_total",
        "adapmoe_lane_bytes_total",
        "adapmoe_lane_on_demand_total",
        "adapmoe_lane_prefetch_total",
        "adapmoe_lane_upgrades_total",
        "adapmoe_lane_busy_ms_total",
        "adapmoe_lane_queued_bytes",
        "adapmoe_lane_queued_jobs",
        "adapmoe_lane_health",
        "adapmoe_lane_retries_total",
        "adapmoe_lane_timeouts_total",
        "adapmoe_lane_failovers_total",
        "adapmoe_device_hits_total",
        "adapmoe_device_misses_total",
        "adapmoe_device_evictions_total",
        "adapmoe_device_resident",
        "adapmoe_device_capacity",
        "adapmoe_device_queued_bytes",
        "adapmoe_device_resident_bytes",
        "adapmoe_device_capacity_bytes",
        "adapmoe_tier_transfers_total",
        "adapmoe_tier_bytes_total",
        "adapmoe_tier_upgrades_total",
        "adapmoe_source_local_bytes_total",
        "adapmoe_source_remote_bytes_total",
        "adapmoe_remote_faults_total",
        "adapmoe_remote_fetches_total",
        "adapmoe_remote_fetched_bytes_total",
        "adapmoe_remote_batched_fetches_total",
        "adapmoe_remote_fetch_time_ms_total",
        "adapmoe_remote_retries_total",
        "adapmoe_remote_checksum_failures_total",
        "adapmoe_remote_reconnects_total",
        "adapmoe_sensitivity_tier_assigns_total",
        "adapmoe_sensitivity_plans_total",
        "adapmoe_sensitivity_evictions_total",
        "adapmoe_sensitivity_prefetches_total",
        "adapmoe_sensitivity_upgrades_total",
        "adapmoe_token_latency_seconds",
        "adapmoe_lane_queue_delay_seconds",
        "adapmoe_remote_fetch_seconds",
    ] {
        assert!(text.contains(family), "exposition missing family {family}:\n{text}");
    }
    for q in ["0.5", "0.95", "0.99"] {
        assert!(text.contains(&format!("adapmoe_token_latency_ms{{quantile=\"{q}\"}}")));
        assert!(text.contains(&format!("adapmoe_lane_queue_delay_ms{{quantile=\"{q}\"}}")));
    }
    // The drain's real int4 tier traffic rides the tier family labels.
    assert!(text.contains("adapmoe_tier_transfers_total{tier=\"int4\"} 8\n"));
    assert!(text.contains("adapmoe_token_latency_seconds_count 3\n"));
}
