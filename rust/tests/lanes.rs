//! Multi-lane transfer-engine tests (artifact-free: synthetic weights,
//! host-math executor). Locks down the two properties `docs/transfer-lanes.md`
//! promises:
//!
//! 1. **Determinism** — consumption follows per-lane completion order, but
//!    output bits are independent of arrival timing (canonical reduction),
//!    so an N-lane engine with wildly skewed wire clocks reproduces the
//!    single-lane serial baseline exactly.
//! 2. **Reservation** — under the `pinned` policy the on-demand lane is
//!    never assigned (and therefore never delayed by) prefetch traffic.

use std::sync::Arc;

use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::transfer::{LaneConfig, LanePolicy, Priority, TransferEngine};
use adapmoe::prop_assert;
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;
use adapmoe::util::rng::Rng;
use adapmoe::util::threadpool::ThreadPool;

fn fixture(
    quant: QuantKind,
    platform: &str,
    scale: f64,
    lanes: LaneConfig,
) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    let store = Arc::new(HostStore::build(&cfg, &w, quant).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::with_lanes(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
        lanes,
    );
    (store, cache, xfer)
}

fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = Rng::new(seed);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// Two lanes with inverted wire speeds (lane 0 calibrated-slow, lane 1
/// instant): round-robin spreads experts 0..6 across them, so the fast
/// lane's experts (odd) land while the slow lane is still on its first.
/// Consumption must follow completions — every odd expert consumed before
/// any even one — and the accumulated output must be bit-identical to the
/// single-lane serial baseline.
#[test]
fn multi_lane_out_of_order_arrival_is_deterministic() {
    let experts: Vec<usize> = (0..6).collect();

    let serial_out = {
        // Slow single lane: all six prefetches are still in flight when the
        // plan joins them, so the queue composition (all pending, expert
        // order) matches the multi-lane run and the canonical reduction
        // compares like with like.
        let (_s, cache, xfer) =
            fixture(QuantKind::Int4, "rtx4090", 1.0, LaneConfig::default());
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 6);
        let (x, coef) = inputs(4, 8, 9);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let par_out = {
        // Lane 0 runs 4× slower than calibrated, lane 1 at 2.5× calibrated
        // speed — inverted wire speeds. The fast lane still needs ~2 ms per
        // expert (vs ~19 ms for the slow lane's first), so the plan join a
        // few µs after the requests cannot race a completion even on a
        // heavily loaded CI runner, and every fast-lane expert lands long
        // before the first slow-lane one.
        let lanes = LaneConfig::new(2, LanePolicy::RoundRobin)
            .with_time_scales(vec![4.0, 0.4]);
        let (_s, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0, lanes);
        for &e in &experts {
            let h = xfer.request((0, e), Priority::Prefetch);
            assert_eq!(h.lane, e % 2, "round-robin must alternate lanes");
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 6, "in-flight prefetches must be joined");
        let (x, coef) = inputs(4, 8, 9);
        let pool = ThreadPool::new(3);
        run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        )
    };

    assert_eq!(serial_out.consumed, experts, "serial drains in plan order");
    // Fast-lane (odd) experts all land before the slow lane finishes its
    // first transfer, so they must all be consumed before any even expert.
    let pos = |e: usize| par_out.consumed.iter().position(|&c| c == e).unwrap();
    for odd in [1usize, 3, 5] {
        for even in [0usize, 2, 4] {
            assert!(
                pos(odd) < pos(even),
                "fast-lane expert {odd} must be consumed before slow-lane {even}: {:?}",
                par_out.consumed
            );
        }
    }
    // Bit-identical output despite opposite consumption order and a
    // completely different lane/timing layout.
    assert_eq!(
        serial_out.acc.data, par_out.acc.data,
        "multi-lane arrival order must not change output bits"
    );
    // Queue delay is attributed to the lane that carried the data; the
    // instant lane's experts sat waiting on compute, so lane 1 appears.
    assert!(
        par_out.queue_delay_by_lane.contains_key(&1),
        "fast-lane queue delay must be attributed: {:?}",
        par_out.queue_delay_by_lane
    );
    let total: u64 = par_out.queue_delay_by_lane.values().sum();
    assert_eq!(total, par_out.queue_delay_ns, "lane split must sum to the total");
}

/// Property: under the `pinned` policy, random request mixes never put a
/// prefetch on the reserved lane 0, and every on-demand load rides it —
/// so prefetch traffic can never starve (queue in front of) an on-demand
/// load, regardless of arrival pattern.
#[test]
fn pinned_assignment_never_starves_reserved_lane() {
    prop::check("pinned-lane-reservation", 12, |rng| {
        let (_s, _cache, xfer) = fixture(
            QuantKind::F32,
            "instant",
            0.0,
            LaneConfig::new(3, LanePolicy::Pinned),
        );
        let cfg = micro_config();
        let mut ids: Vec<(usize, usize)> = (0..cfg.n_layers)
            .flat_map(|l| (0..cfg.n_experts).map(move |e| (l, e)))
            .collect();
        rng.shuffle(&mut ids);
        let n = 8 + rng.usize_below(ids.len() - 8);
        for &id in &ids[..n] {
            let on_demand = rng.chance(0.4);
            let pri = if on_demand { Priority::OnDemand } else { Priority::Prefetch };
            let h = xfer.request(id, pri);
            if on_demand {
                prop_assert!(
                    h.lane == 0,
                    "on-demand {id:?} assigned lane {} not the reserved lane",
                    h.lane
                );
            } else {
                prop_assert!(
                    h.lane != 0,
                    "prefetch {id:?} rode the reserved lane"
                );
            }
        }
        xfer.quiesce().unwrap();
        let snaps = xfer.lane_snapshots();
        prop_assert!(
            snaps[0].prefetch == 0,
            "reserved lane carried {} prefetches",
            snaps[0].prefetch
        );
        prop_assert!(
            snaps[1].on_demand == 0 && snaps[2].on_demand == 0,
            "on-demand leaked onto prefetch lanes"
        );
        prop_assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "queued-load accounting must drain to zero: {snaps:?}"
        );
        Ok(())
    });
}

/// `--lanes 4` with arrivals scrambled across four skewed wire clocks still
/// reproduces the serial single-lane bits (the acceptance-criteria shape).
#[test]
fn four_lane_skewed_clocks_match_single_lane_serial_bits() {
    let experts: Vec<usize> = (0..8).collect();
    let (x, coef) = inputs(4, 8, 21);

    let serial_out = {
        let (_s, cache, xfer) =
            fixture(QuantKind::Int4, "rtx4090", 1.0, LaneConfig::default());
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 8);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let par_out = {
        // Four lanes, each slower than the last: arrival order is roughly
        // the reverse of assignment within each round-robin round. The
        // fastest lane still needs >1 ms per expert so the plan join
        // cannot race a completion.
        let lanes = LaneConfig::new(4, LanePolicy::RoundRobin)
            .with_time_scales(vec![1.2, 0.9, 0.6, 0.3]);
        let (_s, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0, lanes);
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 8);
        let pool = ThreadPool::new(4);
        run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        )
    };

    assert_eq!(serial_out.acc.data, par_out.acc.data);
    assert_eq!(par_out.consumed.len(), 8);
}
