//! Remote expert store integration suite (docs/remote-store.md): a real
//! loopback [`StoreServer`] on one side, a cacheless coordinator store on
//! the other. Locks down what the remote subsystem promises:
//!
//! 1. **Bit-identity** — a remote-fetched expert is byte-for-byte the
//!    local `HostStore` twin at every `QuantKind`, and a transfer engine
//!    draining from a remote store produces outputs bit-identical to the
//!    all-local engine.
//! 2. **Integrity** — any single-byte corruption of the serialized
//!    manifest or an artifact chunk is caught by an FNV checksum; a server
//!    that corrupts every response never yields a resident expert, and the
//!    failure is retryable, not sticky.
//! 3. **Fault fold-in** — flaky connections and corrupt payloads drain
//!    through the PR 6 retry ladder with conserved counters:
//!    `local_bytes + remote_bytes == bytes`, every request resolves once.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::ShardedCache;
use adapmoe::memory::tiered_store::{PrecisionPolicy, TieredStore};
use adapmoe::memory::transfer::{LaneConfig, Priority, TransferEngine};
use adapmoe::net::{connect_store, ArtifactImage, ChaosKnobs, Manifest, StoreServer};
use adapmoe::prop_assert;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;

/// Local reference store + the server publishing its frozen image.
fn serve(kinds: &[QuantKind], knobs: ChaosKnobs) -> (Arc<TieredStore>, StoreServer) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 71);
    let local = Arc::new(TieredStore::build(&cfg, &w, kinds).unwrap());
    // small chunks so every expert spans several checksum windows
    let img = Arc::new(ArtifactImage::from_tiered_chunked(&local, cfg.d_model, cfg.d_ff, 256));
    let srv = StoreServer::spawn_chaotic(Arc::clone(&img), "127.0.0.1:0", knobs).unwrap();
    (local, srv)
}

fn engine_over(tiers: Arc<TieredStore>) -> TransferEngine {
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    TransferEngine::with_tiers(
        tiers,
        PrecisionPolicy::Fixed,
        Arc::new(ShardedCache::single(cache)),
        Platform::preset("instant").unwrap(),
        4,
        0.0,
        LaneConfig::default(),
    )
}

/// Every tier, every expert: the remote store's pinned copy is
/// bit-identical to the local twin — encodings, scales, packed codes, all
/// of it — at every quantization kind.
#[test]
fn remote_fetch_is_bit_identical_to_local_twin_at_every_kind() {
    for kind in [QuantKind::F32, QuantKind::Int8, QuantKind::Int4, QuantKind::Int2] {
        let (local, srv) = serve(&[kind], ChaosKnobs::default());
        let (remote, m) = connect_store(&srv.local_addr()).unwrap();
        assert!(remote.is_remote());
        assert_eq!(m.tiers, vec![kind]);
        let (r, l) = (remote.store(kind), local.store(kind));
        for layer in 0..m.n_layers {
            for expert in 0..m.n_experts {
                let id = (layer, expert);
                assert_eq!(r.get(id), l.get(id), "{} expert {id:?}", kind.name());
                // the clock domain sees identical byte counts too
                assert_eq!(
                    r.expert_transfer_bytes(id),
                    l.expert_transfer_bytes(id),
                    "{} expert {id:?}",
                    kind.name()
                );
            }
        }
        let c = remote.remote_counters().unwrap();
        assert_eq!(
            c.fetches.load(Ordering::Relaxed),
            (m.n_layers * m.n_experts) as u64
        );
    }
}

/// Property: flipping any single byte of a serialized manifest, or any
/// single byte inside an artifact's range, is detected by checksum.
#[test]
fn any_single_byte_corruption_is_detected() {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 71);
    let local = TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap();
    let img = ArtifactImage::from_tiered_chunked(&local, cfg.d_model, cfg.d_ff, 256);
    prop::check("remote-single-byte-corruption", 40, |rng| {
        // a flipped artifact byte fails that entry's chunk verification
        let e = &img.manifest.entries[rng.usize_below(img.manifest.entries.len())];
        let (off, len) = (e.offset as usize, e.len as usize);
        let mut bytes = img.blob[off..off + len].to_vec();
        let at = rng.usize_below(len);
        bytes[at] ^= 1 << rng.usize_below(8);
        prop_assert!(
            e.verify(&bytes, img.manifest.chunk_size).is_err(),
            "flip at artifact byte {at} of {len} went undetected"
        );
        // a flipped manifest byte fails the manifest's own checksum
        let mut mbytes = img.manifest_bytes.clone();
        let mat = rng.usize_below(mbytes.len());
        mbytes[mat] ^= 1 << rng.usize_below(8);
        prop_assert!(
            Manifest::decode(&mbytes).is_err(),
            "flip at manifest byte {mat} of {} went undetected",
            mbytes.len()
        );
        Ok(())
    });
}

/// A server that corrupts every range response can never produce a
/// resident expert — fetch attempts exhaust, the error surfaces, and the
/// slot stays fetchable (a later attempt against a healthy server would
/// succeed; nothing wedges).
#[test]
fn always_corrupt_server_never_yields_a_resident_expert() {
    let (_local, srv) = serve(
        &[QuantKind::Int4],
        ChaosKnobs { corrupt_every: 1, ..ChaosKnobs::default() },
    );
    // the manifest op is not corrupted by the chaos knob, so connect works
    let (remote, _m) = connect_store(&srv.local_addr()).unwrap();
    let store = remote.store(QuantKind::Int4);
    let c = remote.remote_counters().unwrap();
    assert!(store.try_fetch((0, 0)).is_err());
    let failures_after_first = c.checksum_failures.load(Ordering::Relaxed);
    assert!(failures_after_first >= 2, "bounded attempts all rejected");
    // not sticky: the slot is retried (and fails again, attempts growing)
    assert!(store.try_fetch((0, 0)).is_err());
    assert!(c.checksum_failures.load(Ordering::Relaxed) > failures_after_first);
    assert_eq!(c.fetches.load(Ordering::Relaxed), 0, "nothing ever resident");
}

/// The acceptance drill: a transfer engine drains every expert from a
/// *flaky* server (periodic corrupt payloads + dropped connections). The
/// retry ladder absorbs every fault, the drained bits match the all-local
/// twin engine exactly, and the source counters conserve.
#[test]
fn flaky_server_drain_is_bit_identical_with_counters_conserved() {
    let (local, srv) = serve(
        &[QuantKind::Int4],
        // periodic faults, never two in a row: every fetch converges
        // within the client's bounded attempts
        ChaosKnobs { corrupt_every: 5, drop_every: 8, ..ChaosKnobs::default() },
    );
    let (remote, m) = connect_store(&srv.local_addr()).unwrap();
    let remote_engine = engine_over(Arc::new(remote));
    let local_engine = engine_over(Arc::clone(&local));

    let mut issued = 0u64;
    for layer in 0..m.n_layers {
        for expert in 0..m.n_experts {
            let id = (layer, expert);
            let rh = remote_engine.request(id, Priority::OnDemand);
            let lh = local_engine.request(id, Priority::OnDemand);
            assert_eq!(
                rh.wait_full().w1.data,
                lh.wait_full().w1.data,
                "expert {id:?} drained different bits"
            );
            issued += 1;
        }
    }
    remote_engine.quiesce().unwrap();
    local_engine.quiesce().unwrap();

    // every request resolved exactly once, all bytes remote-sourced
    let s = remote_engine.source_snapshot();
    let bytes = remote_engine.stats.bytes.load(Ordering::Relaxed);
    assert_eq!(remote_engine.stats.transfers.load(Ordering::Relaxed), issued);
    assert_eq!(s.local_bytes + s.remote_bytes, bytes);
    assert_eq!(s.remote_bytes, bytes, "first touches all come off the wire");
    assert_eq!(s.fetches, issued);
    assert_eq!(s.remote_faults, 0, "periodic faults never exhaust attempts");
    // the chaos schedule guarantees both fault species actually fired
    assert!(s.checksum_failures > 0, "{s:?}");
    assert!(s.reconnects > 0, "{s:?}");
    assert!(s.retries > 0, "{s:?}");
    assert!(s.fetch_ms >= 0.0);

    // a re-transfer of a pinned expert is local-sourced: the wire is only
    // paid once per expert
    let h = remote_engine.request((0, 0), Priority::OnDemand);
    h.wait_full();
    remote_engine.quiesce().unwrap();
    let s2 = remote_engine.source_snapshot();
    assert_eq!(s2.local_bytes, h.bytes as u64);
    assert_eq!(s2.remote_bytes, s.remote_bytes);

    // the local twin engine reports an all-zero source block
    let ls = local_engine.source_snapshot();
    assert_eq!(ls.remote_bytes, 0);
    assert_eq!(ls.fetches, 0);
    assert!(local_engine.stats.bytes.load(Ordering::Relaxed) > 0);
    assert_eq!(ls.local_bytes, local_engine.stats.bytes.load(Ordering::Relaxed));
}
