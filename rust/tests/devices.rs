//! Sharded device-backend tests (artifact-free: synthetic weights,
//! host-math executor). Locks down what `docs/sharded-backends.md`
//! promises:
//!
//! 1. **Determinism** — out-of-order arrivals *across devices* (each
//!    device's lane group running a different wire clock) produce
//!    bit-identical layer output to the serial single-device baseline,
//!    because the drain merges arrivals in completion order but reduces
//!    in canonical queue order.
//! 2. **Conservation** — per-device hit/miss/eviction counters sum to
//!    exactly the figures a single global cache would have counted, and
//!    the per-device queued-bytes gauges drain to zero.
//! 3. **Ownership** — experts (including staged-prefetch promotions)
//!    only ever land on the shard their placement owns.

use std::sync::Arc;

use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::{Placement, ShardedCache};
use adapmoe::memory::transfer::{LaneConfig, LanePolicy, Priority, TransferEngine};
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::rng::Rng;
use adapmoe::util::threadpool::ThreadPool;

fn store(quant: QuantKind) -> Arc<HostStore> {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    Arc::new(HostStore::build(&cfg, &w, quant).unwrap())
}

fn single_fixture(quant: QuantKind, platform: &str, scale: f64)
    -> (Arc<DeviceCache>, TransferEngine) {
    let store = store(quant);
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::new(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
    );
    (cache, xfer)
}

fn sharded_fixture(
    quant: QuantKind,
    devices: usize,
    placement: Placement,
    platform: &str,
    scale: f64,
    lanes: LaneConfig,
) -> (Arc<ShardedCache>, TransferEngine) {
    let store = store(quant);
    let cache = Arc::new(ShardedCache::new(vec![vec![8, 8]; devices], placement));
    let xfer = TransferEngine::with_devices(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
        lanes,
    );
    (cache, xfer)
}

fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = Rng::new(seed);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// The acceptance-criteria shape: `--devices 4` with each device's lane
/// running a different wire clock scrambles cross-device arrival order,
/// yet the layer output is bit-identical to the serial single-device
/// baseline, and every transfer rode its owning device's lane.
#[test]
fn four_device_out_of_order_arrivals_match_single_device_serial_bits() {
    let experts: Vec<usize> = (0..8).collect();
    let (x, coef) = inputs(4, 8, 33);

    let serial_out = {
        let (cache, xfer) = single_fixture(QuantKind::Int4, "rtx4090", 1.0);
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 8);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let par_out = {
        // 4 devices × 4 lanes: hash placement spreads layer 0's experts
        // over all devices; lane l serves device l, and each lane's wire
        // clock differs, so completion order across devices is roughly
        // inverse to request order. The fastest lane still needs >1 ms
        // per expert so the plan join cannot race a completion.
        let lanes = LaneConfig::new(4, LanePolicy::RoundRobin)
            .with_time_scales(vec![1.2, 0.9, 0.6, 0.3]);
        let (cache, xfer) = sharded_fixture(
            QuantKind::Int4,
            4,
            Placement::ExpertHash,
            "rtx4090",
            1.0,
            lanes,
        );
        let mut devices_used = std::collections::HashSet::new();
        for &e in &experts {
            let id = (0usize, e);
            let dev = cache.device_of(id);
            devices_used.insert(dev);
            let h = xfer.request(id, Priority::Prefetch);
            assert_eq!(
                h.lane % 4,
                dev,
                "expert {id:?} must ride its owning device's lane group"
            );
        }
        assert!(
            devices_used.len() >= 3,
            "hash placement should spread layer 0 over devices: {devices_used:?}"
        );
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 8, "in-flight prefetches must be joined");
        let pool = ThreadPool::new(4);
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        xfer.quiesce().unwrap();
        // every consumed expert was promoted into its owning shard only
        for &e in &experts {
            let dev = cache.device_of((0, e));
            assert!(cache.shard(dev).contains((0, e)));
            for other in (0..4).filter(|&d| d != dev) {
                assert!(
                    !cache.shard(other).contains((0, e)),
                    "expert {e} leaked to device {other}"
                );
            }
        }
        out
    };

    assert_eq!(serial_out.consumed, experts, "serial drains in plan order");
    assert_eq!(par_out.consumed.len(), 8);
    assert_ne!(
        par_out.consumed, experts,
        "skewed per-device clocks must scramble cross-device arrival order"
    );
    assert_eq!(
        serial_out.acc.data, par_out.acc.data,
        "cross-device arrival order must not change output bits"
    );
}

/// Per-device counters are a partition of the old global counters: their
/// sums equal `ShardedCache::stats()`, which a single-device run counts
/// identically, and the queued-bytes gauges drain to zero.
#[test]
fn per_device_counters_sum_to_global_and_queues_drain() {
    let (cache, xfer) = sharded_fixture(
        QuantKind::F32,
        2,
        Placement::ExpertHash,
        "instant",
        0.0,
        LaneConfig::new(2, LanePolicy::RoundRobin),
    );
    // misses: plan for uncached experts issues on-demand loads
    let plan = build_plan(0, &[0, 1, 2, 3], &[], &cache, &xfer);
    for (_, h) in plan.pending_items() {
        h.wait_full();
    }
    xfer.quiesce().unwrap();
    // hits: now-resident experts come back ready
    let plan2 = build_plan(0, &[0, 1, 2, 3], &[], &cache, &xfer);
    assert_eq!(plan2.n_ready(), 4);
    let (h, m, e) = cache.stats();
    assert_eq!((h, m), (4, 4), "4 misses then 4 hits");
    let snaps = xfer.device_snapshots();
    assert_eq!(snaps.len(), 2);
    assert_eq!(snaps.iter().map(|s| s.hits).sum::<u64>(), h);
    assert_eq!(snaps.iter().map(|s| s.misses).sum::<u64>(), m);
    assert_eq!(snaps.iter().map(|s| s.evictions).sum::<u64>(), e);
    assert!(
        snaps.iter().all(|s| s.queued_bytes == 0),
        "device queued-bytes must drain to zero: {snaps:?}"
    );
    assert!(
        snaps.iter().all(|s| s.hits + s.misses > 0),
        "both shards should see traffic under hash placement: {snaps:?}"
    );
}

/// The sharded variant of the staging-promotion contention test: a
/// staged prefetch consumed by `build_plan` promotes into the *owning*
/// shard only, evicting that shard's LRU entry when its layer is full.
#[test]
fn staged_prefetch_promotes_into_owning_shard_only() {
    let (cache, xfer) = sharded_fixture(
        QuantKind::F32,
        2,
        Placement::LayerSliced,
        "instant",
        0.0,
        LaneConfig::new(2, LanePolicy::RoundRobin),
    );
    // layer 1 is owned by device 1 (2 layers over 2 devices)
    assert_eq!(cache.device_of((1, 6)), 1);
    xfer.request((1, 6), Priority::Prefetch).wait_full();
    xfer.quiesce().unwrap();
    assert!(xfer.staging_contains((1, 6)));
    assert!(!cache.contains((1, 6)));
    let plan = build_plan(1, &[6], &[], &cache, &xfer);
    assert_eq!(plan.n_ready(), 1, "staged expert must come back ready");
    assert_eq!(plan.on_demand_issued, 0);
    assert!(cache.shard(1).contains((1, 6)), "promotion lands on the owner");
    assert!(!cache.shard(0).contains((1, 6)), "non-owning shard stays clean");
    assert!(!xfer.staging_contains((1, 6)));

    // contention: shrink the owner's layer-1 budget to 1 and promote a
    // second staged expert — the first promotion is evicted, the shard
    // never overflows, and device 0 is untouched throughout.
    cache.shard(1).set_allocation(&[0, 1]);
    xfer.request((1, 7), Priority::Prefetch).wait_full();
    xfer.quiesce().unwrap();
    let plan = build_plan(1, &[7], &[], &cache, &xfer);
    assert_eq!(plan.n_ready(), 1);
    assert!(cache.shard(1).contains((1, 7)));
    assert!(!cache.shard(1).contains((1, 6)), "LRU entry evicted by promotion");
    assert_eq!(cache.shard(1).resident(1).len(), 1, "owner stays at capacity");
    assert_eq!(cache.shard(0).len(), 0, "non-owning shard saw no traffic");
}
