//! SensitivityMap determinism + property suite (artifact-free).
//!
//! Locks down the contract of docs/sensitivity.md:
//!
//! * the **uniform** map is the identity everywhere — an engine with the
//!   uniform map explicitly installed (and its prefetches routed through
//!   the sensitivity-aware priority/slack helpers) produces bits and byte
//!   counters identical to an untouched engine, under both the serial
//!   drain and a 4-lane out-of-order completion drain;
//! * offline tier assignment is **monotone in importance**: a more
//!   important layer never rides a lower precision tier (property test
//!   over random Fisher profiles);
//! * importance-weighted eviction **never evicts the last servable
//!   entry** of a layer: victims are only taken when a layer is at
//!   capacity, the just-inserted entry is never the victim, and a
//!   single-slot layer degenerates to plain LRU with zero bias.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::prefetch;
use adapmoe::coordinator::profile::Profile;
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::coordinator::sensitivity::{SensitivityMap, SensitivityPolicy};
use adapmoe::memory::device_cache::{DeviceCache, ResidentMeta};
use adapmoe::memory::host_store::ExpertF32;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::ShardedCache;
use adapmoe::memory::tiered_store::{PrecisionPolicy, TieredStore};
use adapmoe::memory::transfer::{
    LaneConfig, LanePolicy, Priority, SensitivitySnapshot, TransferEngine,
};
use adapmoe::model::ExpertId;
use adapmoe::prop_assert;
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;
use adapmoe::util::threadpool::ThreadPool;

const SEED: u64 = 47;

fn tiered_engine(lanes: LaneConfig) -> (Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, SEED);
    let tiers = Arc::new(
        TieredStore::build(&cfg, &w, &[QuantKind::Int2, QuantKind::Int8]).unwrap(),
    );
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::with_tiers(
        tiers,
        PrecisionPolicy::Urgency,
        Arc::new(ShardedCache::single(Arc::clone(&cache))),
        Platform::preset("rtx4090").unwrap(),
        4,
        1.0,
        lanes,
    );
    (cache, xfer)
}

fn inputs(b: usize, n_experts: usize) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = prop::rng_for("sensitivity-inputs", 9);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// One prefetch-heavy layer pass. `explicit_uniform` routes every request
/// through the sensitivity helpers (prioritize + prefetch_slack) with the
/// uniform map freshly installed; `false` is the untouched historical
/// engine. `parallel` drains completion-driven on 3 worker threads so
/// mixed-tier bytes land out of order.
fn run_pass(explicit_uniform: bool, parallel: bool) -> (Vec<f32>, u64, u64) {
    let cfg = micro_config();
    let computes: Vec<usize> = (0..6).collect();
    // spread of router probabilities → mixed urgency slacks → mixed tiers
    let probs = [0.95, 0.2, 0.8, 0.05, 0.6, 0.4];
    let (x, coef) = inputs(4, cfg.n_experts);

    let (cache, xfer) = tiered_engine(LaneConfig::new(
        if parallel { 4 } else { 1 },
        LanePolicy::LeastQueuedBytes,
    ));
    let map = Arc::new(SensitivityMap::uniform(cfg.n_layers));
    if explicit_uniform {
        xfer.set_sensitivity(Arc::clone(&map));
        cache.set_eviction_weights(map.eviction_weights());
    }

    // enqueue inverted so plan order != arrival order in the OOO drain
    let reqs: Vec<(ExpertId, f64)> =
        computes.iter().rev().map(|&e| ((0usize, e), probs[e])).collect();
    if explicit_uniform {
        for (id, p) in prefetch::prioritize(reqs, &map) {
            xfer.request_with_slack(id, Priority::Prefetch, map.prefetch_slack(id.0, p));
        }
    } else {
        for (id, p) in reqs {
            xfer.request_with_slack(id, Priority::Prefetch, 1.0 - p);
        }
    }

    let plan = build_plan(0, &computes, &[], &cache, &xfer);
    assert_eq!(plan.on_demand_issued, 0, "must join the in-flight transfers");
    let out = if parallel {
        let pool = ThreadPool::new(3);
        run_layer_parallel(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache, &xfer, &pool)
    } else {
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };
    xfer.quiesce().unwrap();

    // the uniform map never counts a shaped decision
    assert_eq!(
        xfer.sensitivity_snapshot(),
        SensitivitySnapshot::default(),
        "uniform map must leave every consumer counter at zero"
    );
    assert_eq!(cache.bias_evictions(), 0);
    (
        out.acc.data,
        xfer.stats.bytes.load(Ordering::Relaxed),
        xfer.stats.transfers.load(Ordering::Relaxed),
    )
}

/// Tentpole acceptance: installing the uniform map changes nothing — not
/// one bit of output, not one wire byte — whether the drain is serial or
/// completion-driven across 4 lanes.
#[test]
fn uniform_map_is_bit_identical_to_baseline_serial_and_ooo() {
    let (base_bits, base_bytes, base_xfers) = run_pass(false, false);
    let (uni_bits, uni_bytes, uni_xfers) = run_pass(true, false);
    assert_eq!(base_bits, uni_bits, "serial drain: uniform map changed output bits");
    assert_eq!(base_bytes, uni_bytes, "serial drain: uniform map changed wire bytes");
    assert_eq!(base_xfers, uni_xfers);

    let (base_bits, base_bytes, base_xfers) = run_pass(false, true);
    let (uni_bits, uni_bytes, uni_xfers) = run_pass(true, true);
    assert_eq!(base_bits, uni_bits, "4-lane OOO drain: uniform map changed output bits");
    assert_eq!(base_bytes, uni_bytes, "4-lane OOO drain: uniform map changed wire bytes");
    assert_eq!(base_xfers, uni_xfers);
}

/// Serial and OOO drains agree with each other under the explicit map —
/// the canonical-reduction guarantee survives the sensitivity plumbing.
#[test]
fn uniform_map_ooo_drain_matches_serial_drain() {
    let (serial_bits, ..) = run_pass(true, false);
    let (par_bits, ..) = run_pass(true, true);
    assert_eq!(serial_bits, par_bits);
}

/// Offline importance → tier assignment is monotone: for any random
/// Fisher profile, a layer at least as important as another never rides
/// a lower tier, and the most sensitive layer pins the top tier.
#[test]
fn tier_assignment_monotone_in_importance() {
    let tiers = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8];
    prop::check("tier-floor-monotone-in-importance", 60, |rng| {
        let n = 2 + rng.usize_below(8);
        let mut p = Profile::synthetic(n);
        p.sensitivity = (0..n).map(|_| rng.f64() * 10.0).collect();
        let map = SensitivityMap::from_profile(&p, SensitivityPolicy::Profile);
        for i in 0..n {
            for j in 0..n {
                if map.importance(i) <= map.importance(j) {
                    let (ti, tj) = (map.tier_for(i, &tiers), map.tier_for(j, &tiers));
                    prop_assert!(
                        ti.bits() <= tj.bits(),
                        "importance {:.3} <= {:.3} but tier {} > {}",
                        map.importance(i),
                        map.importance(j),
                        ti.name(),
                        tj.name()
                    );
                }
            }
        }
        // the argmax layer has importance exactly 1.0 → top tier
        if let Some(hi) = (0..n).max_by(|&a, &b| {
            p.sensitivity[a].partial_cmp(&p.sensitivity[b]).unwrap()
        }) {
            if p.sensitivity[hi] > 0.0 {
                prop_assert!(
                    map.tier_for(hi, &tiers) == tiers[tiers.len() - 1],
                    "most sensitive layer must ride the top tier"
                );
            }
        }
        // assignments table agrees with per-layer queries
        let table = map.tier_assignments(&tiers);
        for (l, &k) in table.iter().enumerate() {
            prop_assert!(k == map.tier_for(l, &tiers));
        }
        Ok(())
    });
}

fn dummy() -> Arc<ExpertF32> {
    Arc::new(ExpertF32 {
        w1: Tensor::zeros(vec![2, 2]),
        w3: Tensor::zeros(vec![2, 2]),
        w2: Tensor::zeros(vec![2, 2]),
    })
}

/// Importance-weighted eviction never evicts the last servable entry:
/// a victim is taken only when the layer is at capacity (so the layer
/// never goes empty), the entry just inserted is never the victim, and
/// a single-slot layer degenerates to plain LRU with zero bias.
#[test]
fn weighted_eviction_never_evicts_last_servable_entry() {
    prop::check("weighted-eviction-preserves-servability", 40, |rng| {
        let n_layers = 2;
        let cap = 1 + rng.usize_below(3);
        let cache = DeviceCache::new(vec![cap; n_layers]);
        cache.set_eviction_weights(Some(
            (0..n_layers).map(|_| rng.f64()).collect(),
        ));
        let kinds = [
            (QuantKind::Int2, 100usize),
            (QuantKind::Int8, 400usize),
        ];
        for _ in 0..60 {
            let layer = rng.usize_below(n_layers);
            let e = rng.usize_below(6);
            let (kind, bytes) = kinds[rng.usize_below(2)];
            let before = cache.resident(layer).len();
            let already = cache.contains((layer, e));
            let evicted = cache.insert_tiered((layer, e), dummy(), ResidentMeta { kind, bytes });
            let after = cache.resident(layer).len();
            prop_assert!(after >= 1, "layer {layer} left empty after insert");
            if let Some(v) = evicted {
                prop_assert!(v != (layer, e), "evicted the entry just inserted");
                prop_assert!(v.0 == layer, "evicted from another layer");
                prop_assert!(
                    before == cap && !already,
                    "victim taken while layer below capacity ({before}/{cap})"
                );
                prop_assert!(after == cap, "layer not full after forced eviction");
                prop_assert!(
                    !cache.contains(v),
                    "victim still resident after eviction"
                );
            }
            prop_assert!(after <= cap, "layer over capacity");
        }
        if cap == 1 {
            prop_assert!(
                cache.bias_evictions() == 0,
                "a single-slot layer must keep exact LRU (no bias)"
            );
        }
        Ok(())
    });
}

/// The uniform map's helper surface is the identity (the exact values the
/// engine consumers rely on for the bit-for-bit guarantee).
#[test]
fn uniform_map_helpers_are_identity() {
    let map = SensitivityMap::uniform(4);
    assert!(map.is_uniform());
    assert_eq!(map.upgrade_order(4), vec![0, 1, 2, 3]);
    assert_eq!(map.eviction_weights(), None);
    for l in 0..4 {
        assert_eq!(map.importance(l), 1.0);
        for p in [0.0, 0.25, 0.9] {
            assert_eq!(map.prefetch_slack(l, p), 1.0 - p);
        }
    }
    let reqs: Vec<(ExpertId, f64)> = vec![((0, 3), 0.1), ((1, 0), 0.9)];
    assert_eq!(prefetch::prioritize(reqs.clone(), &map), reqs);
}
