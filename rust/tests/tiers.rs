//! Tiered mixed-precision store determinism + policy suite (artifact-free).
//!
//! Locks down the acceptance properties of docs/tiered-precision.md:
//!
//! * a single-tier tiered engine is **bit-for-bit** the historical
//!   `--quant` engine — same output bits, same transfer byte counts;
//! * out-of-order multi-tier arrivals are deterministic: the
//!   completion-driven drain reproduces the serial drain's bits no matter
//!   which tier's bytes land first;
//! * degrade-instead-of-miss never stalls the executor when a lower-tier
//!   copy is resident;
//! * background upgrades never preempt urgent loads (the pinned-lane
//!   reservation holds for `Priority::Upgrade`);
//! * the wire bytes the engine charges equal `QuantExpert::size_bytes`
//!   at every tier.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::scheduler::{build_plan, build_plan_tiered, ScheduleMode, TierMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::ShardedCache;
use adapmoe::memory::tiered_store::{PrecisionPolicy, TieredStore};
use adapmoe::memory::transfer::{LaneConfig, LanePolicy, Priority, TransferEngine};
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;
use adapmoe::util::threadpool::ThreadPool;

const SEED: u64 = 41;

fn legacy_engine(
    kind: QuantKind,
    platform: &str,
    scale: f64,
) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, SEED);
    let store = Arc::new(HostStore::build(&cfg, &w, kind).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::new(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
    );
    (store, cache, xfer)
}

fn tiered_engine(
    kinds: &[QuantKind],
    precision: PrecisionPolicy,
    lanes: LaneConfig,
    platform: &str,
    scale: f64,
) -> (Arc<TieredStore>, Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, SEED);
    let tiers = Arc::new(TieredStore::build(&cfg, &w, kinds).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::with_tiers(
        Arc::clone(&tiers),
        precision,
        Arc::new(ShardedCache::single(Arc::clone(&cache))),
        Platform::preset(platform).unwrap(),
        4,
        scale,
        lanes,
    );
    (tiers, cache, xfer)
}

fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = prop::rng_for("tiers-inputs", seed);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// `--tiers int4` (one tier, no upgrades) is the current `--quant int4`
/// path: same output bits, same transfer byte counts.
#[test]
fn single_tier_is_bit_for_bit_the_quant_path() {
    let computes: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(4, 8, 7);

    let (legacy_store, legacy_cache, legacy) = legacy_engine(QuantKind::Int4, "instant", 0.0);
    let plan = build_plan(0, &computes, &[], &legacy_cache, &legacy);
    let legacy_out = run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &legacy_cache);
    legacy.quiesce().unwrap();

    let (tiers, tiered_cache, tiered) = tiered_engine(
        &[QuantKind::Int4],
        PrecisionPolicy::Fixed,
        LaneConfig::default(),
        "instant",
        0.0,
    );
    let plan = build_plan(0, &computes, &[], &tiered_cache, &tiered);
    let tiered_out = run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &tiered_cache);
    tiered.quiesce().unwrap();

    // identical logit contributions, bit for bit
    assert_eq!(legacy_out.acc.data, tiered_out.acc.data);
    // identical wire byte counts, total and per expert
    assert_eq!(
        legacy.stats.bytes.load(Ordering::Relaxed),
        tiered.stats.bytes.load(Ordering::Relaxed)
    );
    assert_eq!(
        legacy.stats.transfers.load(Ordering::Relaxed),
        tiered.stats.transfers.load(Ordering::Relaxed)
    );
    for &e in &computes {
        assert_eq!(
            legacy_store.expert_transfer_bytes((0, e)),
            tiers.expert_transfer_bytes((0, e), QuantKind::Int4)
        );
    }
    // the tiered engine's single tier carries everything
    let snap = tiered.tier_snapshots();
    assert_eq!(snap.len(), 1);
    assert_eq!(snap[0].kind, QuantKind::Int4);
    assert_eq!(snap[0].bytes, tiered.stats.bytes.load(Ordering::Relaxed));
    assert_eq!(snap[0].upgrades, 0);
}

/// Mixed-tier transfers arriving out of order: int2 bytes land long
/// before int8 bytes on the calibrated link, so the completion-driven
/// drain consumes them in a different order than the serial drain — and
/// must still produce the same bits (canonical reduction).
#[test]
fn multi_tier_ooo_arrivals_are_deterministic() {
    let kinds = [QuantKind::Int2, QuantKind::Int8];
    let computes: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(4, 8, 9);
    // pin tiers per expert: evens ride int2 (fast), odds int8 (slow)
    let tier_of = |e: usize| if e % 2 == 0 { QuantKind::Int2 } else { QuantKind::Int8 };

    let run = |completion: bool| {
        let (_tiers, cache, xfer) = tiered_engine(
            &kinds,
            PrecisionPolicy::Urgency,
            LaneConfig::default(),
            "rtx4090",
            1.0,
        );
        // enqueue in inverted order so plan order != arrival order
        for e in computes.iter().rev() {
            xfer.request_at((0, *e), Priority::Prefetch, tier_of(*e));
        }
        let plan = build_plan(0, &computes, &[], &cache, &xfer);
        assert_eq!(plan.on_demand_issued, 0, "must join the in-flight transfers");
        let out = if completion {
            let pool = ThreadPool::new(3);
            run_layer_parallel(
                &plan,
                &x,
                &coef,
                ScheduleMode::ExpertWise,
                4,
                &cache,
                &xfer,
                &pool,
            )
        } else {
            run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
        };
        xfer.quiesce().unwrap();
        // every expert's resident copy records the tier it rode
        for &e in &computes {
            assert_eq!(cache.resident_meta((0, e)).unwrap().kind, tier_of(e));
        }
        out
    };

    let serial = run(false);
    let par = run(true);
    assert_eq!(serial.consumed, computes, "serial drains in plan order");
    assert_eq!(
        serial.acc.data, par.acc.data,
        "mixed-tier OOO arrivals must not change the output bits"
    );
    // per-tier queue delay was attributed for both tiers in the serial
    // (head-of-line) drain
    assert!(serial.queue_delay_by_tier.contains_key(&QuantKind::Int2.tier_index()));
}

/// Degrade-instead-of-miss: a resident lower-tier copy is served ready —
/// the executor never parks on the completion board for it.
#[test]
fn degrade_never_stalls_executor_on_resident_low_tier() {
    let (_tiers, cache, xfer) = tiered_engine(
        &[QuantKind::Int2, QuantKind::Int8],
        PrecisionPolicy::Urgency,
        LaneConfig::default(),
        "instant",
        0.0,
    );
    let computes: Vec<usize> = (0..3).collect();
    // land int2 (below-preferred) copies
    for &e in &computes {
        xfer.request((0, e), Priority::OnDemand).wait_full();
    }
    xfer.quiesce().unwrap();
    for &e in &computes {
        assert_eq!(cache.resident_meta((0, e)).unwrap().kind, QuantKind::Int2);
    }

    let plan = build_plan_tiered(0, &computes, &[], &cache, &xfer, TierMode::Degrade);
    assert_eq!(plan.n_ready(), 3, "degraded residents must come back ready");
    assert_eq!(plan.n_pending(), 0);
    assert_eq!(plan.on_demand_issued, 0);
    assert_eq!(plan.degraded, 3);
    let (x, coef) = inputs(2, 8, 11);
    let pool = ThreadPool::new(2);
    let out = run_layer_parallel(
        &plan,
        &x,
        &coef,
        ScheduleMode::ExpertWise,
        4,
        &cache,
        &xfer,
        &pool,
    );
    assert_eq!(out.stall_ns, 0, "no pending work: the drain must never park");
    assert_eq!(out.queue_delay_ns, 0);

    // strict mode re-fetches the same residents at the preferred tier
    let plan = build_plan_tiered(0, &computes, &[], &cache, &xfer, TierMode::Strict);
    assert_eq!(plan.n_pending(), 3);
    assert_eq!(plan.degraded, 0);
    for (_, h) in plan.pending_items() {
        assert_eq!(h.kind, QuantKind::Int8);
        h.wait_full();
    }
    xfer.quiesce().unwrap();
}

/// The pinned-lane reservation holds for upgrades: they ride the
/// non-reserved lanes, and an urgent load issued *after* a burst of slow
/// upgrades still completes first.
#[test]
fn upgrades_never_preempt_urgent_loads() {
    // lane 0 (reserved, on-demand) at instant speed; lane 1 calibrated —
    // upgrade traffic parks there for milliseconds.
    let (_tiers, cache, xfer) = tiered_engine(
        &[QuantKind::Int2, QuantKind::Int8],
        PrecisionPolicy::Urgency,
        LaneConfig::new(2, LanePolicy::Pinned).with_time_scales(vec![0.0, 1.0]),
        "rtx4090",
        1.0,
    );
    // land int2 residents to upgrade (urgent lane, instant)
    for e in 0..3 {
        xfer.request((0, e), Priority::OnDemand).wait_full();
    }
    xfer.quiesce().unwrap();
    // a burst of upgrades: all must avoid the reserved lane
    let ups: Vec<_> = (0..3)
        .map(|e| xfer.request_at((0, e), Priority::Upgrade, QuantKind::Int8))
        .collect();
    for up in &ups {
        assert_ne!(up.lane, 0, "upgrade must never ride the reserved lane");
    }
    // an urgent load issued afterwards completes while upgrades drag on
    let urgent = xfer.request((1, 0), Priority::OnDemand);
    assert_eq!(urgent.lane, 0);
    urgent.wait_full();
    assert!(
        ups.iter().any(|u| !u.is_complete()),
        "urgent load must finish before the slow upgrade burst drains"
    );
    xfer.quiesce().unwrap();
    // every upgrade landed and promoted its resident entry
    for e in 0..3 {
        assert_eq!(cache.resident_meta((0, e)).unwrap().kind, QuantKind::Int8);
    }
    let snaps = xfer.lane_snapshots();
    assert_eq!(snaps[0].upgrades, 0, "reserved lane carried no upgrades");
    assert_eq!(snaps[1].upgrades, 3);
    assert_eq!(xfer.stats.upgrades.load(Ordering::Relaxed), 3);
}

/// The wire bytes the engine charges at every tier equal the stored
/// `QuantExpert::size_bytes` — the property that keeps the simulated
/// link, the gauges and the byte-denominated cache in one currency.
#[test]
fn engine_charges_match_quant_expert_size_bytes_per_tier() {
    let kinds = [QuantKind::Int2, QuantKind::Int4, QuantKind::Int8];
    let (tiers, _cache, xfer) = tiered_engine(
        &kinds,
        PrecisionPolicy::Urgency,
        LaneConfig::default(),
        "instant",
        0.0,
    );
    let cfg = micro_config();
    let mut rng = prop::rng_for("tiers-charge-stream", 13);
    let mut expect_total = 0u64;
    for i in 0..12 {
        let id = (i % cfg.n_layers, rng.usize_below(cfg.n_experts));
        let kind = kinds[i % kinds.len()];
        let before = xfer.stats.bytes.load(Ordering::Relaxed);
        let h = xfer.request_at(id, Priority::OnDemand, kind);
        assert_eq!(h.bytes, tiers.store(kind).get(id).size_bytes());
        h.wait_full();
        xfer.quiesce().unwrap();
        let delta = xfer.stats.bytes.load(Ordering::Relaxed) - before;
        assert_eq!(
            delta as usize,
            tiers.store(kind).get(id).size_bytes(),
            "charged bytes must equal the stored encoding at {id:?}/{}",
            kind.name()
        );
        expect_total += delta;
    }
    assert_eq!(xfer.stats.bytes.load(Ordering::Relaxed), expect_total);
    // per-tier counters partition the total exactly
    let by_tier: u64 = xfer.tier_snapshots().iter().map(|t| t.bytes).sum();
    assert_eq!(by_tier, expect_total);
}
