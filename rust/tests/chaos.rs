//! Chaos harness: scripted fault plans driven against live multi-lane
//! transfer engines and the layer executor (artifact-free: synthetic
//! weights, host math). Locks down what docs/fault-tolerance.md promises:
//!
//! 1. **Clean quiesce** — every scripted [`FaultPlan`] drains to an empty
//!    in-flight registry; nothing strands, nothing hangs.
//! 2. **Counter conservation** — every request resolves exactly once:
//!    `transfers + skipped_cached + failed == requests`, and the per-lane
//!    queued-bytes/jobs gauges return to zero through any sequence of
//!    timeouts, retries and lane→lane failovers.
//! 3. **Determinism** — recoverable faults (flaky drops, dead lanes with
//!    failover) leave output bits identical to the fault-free run, and a
//!    replayed plan reproduces a degraded run bit-for-bit.
//! 4. **Idempotent failover** — hammering the fault pump from many threads
//!    while a lane dies never double-lands or loses a transfer.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use adapmoe::coordinator::executor::{run_layer_parallel, run_layer_serial};
use adapmoe::coordinator::scheduler::{build_plan, ScheduleMode};
use adapmoe::memory::device_cache::DeviceCache;
use adapmoe::memory::faults::FaultPlan;
use adapmoe::memory::host_store::HostStore;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::transfer::{
    FaultConfig, LaneConfig, LaneHealth, LanePolicy, Priority, TransferEngine,
};
use adapmoe::prop_assert;
use adapmoe::tensor::Tensor;
use adapmoe::testutil::{micro_config, synthetic_weights};
use adapmoe::util::prop;
use adapmoe::util::threadpool::ThreadPool;

fn fixture(
    quant: QuantKind,
    platform: &str,
    scale: f64,
    lanes: LaneConfig,
) -> (Arc<HostStore>, Arc<DeviceCache>, TransferEngine) {
    let cfg = micro_config();
    let w = synthetic_weights(&cfg, 11);
    let store = Arc::new(HostStore::build(&cfg, &w, quant).unwrap());
    let cache = Arc::new(DeviceCache::new(vec![8, 8]));
    let xfer = TransferEngine::with_lanes(
        Arc::clone(&store),
        Arc::clone(&cache),
        Platform::preset(platform).unwrap(),
        4,
        scale,
        lanes,
    );
    (store, cache, xfer)
}

fn inputs(b: usize, n_experts: usize, seed: u64) -> (Tensor, Vec<Vec<f32>>) {
    let cfg = micro_config();
    let mut rng = prop::rng_for("chaos-inputs", seed);
    let x = Tensor::new(
        vec![b, cfg.d_model],
        (0..b * cfg.d_model).map(|_| rng.f32() - 0.5).collect(),
    )
    .unwrap();
    let coef: Vec<Vec<f32>> = (0..n_experts)
        .map(|_| (0..b).map(|_| rng.f32()).collect())
        .collect();
    (x, coef)
}

/// Every scripted plan — halts, slowdowns, flaky drops, delays, a full
/// device blackout — must quiesce clean with conserved counters and
/// drained gauges, no matter where in the request stream it strikes.
#[test]
fn scripted_plans_quiesce_clean_and_conserve_counters() {
    let plans = [
        "0:halt:2",
        "0:flaky:1:2;1:slow:0:4",
        "1:delay:0:2;2:halt:1",
        "1:slow:2:8;1:flaky:0:3;3:halt:0",
        "2:blackout:0",
    ];
    for spec in plans {
        let plan = FaultPlan::parse(spec).unwrap();
        let (_s, _cache, xfer) = fixture(
            QuantKind::F32,
            "instant",
            0.0,
            LaneConfig::new(3, LanePolicy::RoundRobin),
        );
        // 3 fresh experts per step (ids stay unique: a duplicate of an
        // in-flight id joins its ticket instead of opening a new one),
        // faults injected between waves exactly as Engine::decode_step does
        let mut issued = 0u64;
        let mut next = 0usize;
        for step in 0..=plan.last_step() + 1 {
            xfer.apply_fault_plan(&plan, step);
            for _ in 0..3 {
                let id = (next % 2, next / 2 % 8);
                next += 1;
                let pri = if next % 3 == 0 { Priority::OnDemand } else { Priority::Prefetch };
                xfer.request(id, pri);
                issued += 1;
            }
        }
        let report = xfer.quiesce().unwrap_or_else(|e| panic!("plan '{spec}': {e:#}"));
        // conservation: every request resolved exactly once
        let transfers = xfer.stats.transfers.load(Ordering::Relaxed);
        let skipped = xfer.stats.skipped_cached.load(Ordering::Relaxed);
        let failed = xfer.stats.failed.load(Ordering::Relaxed);
        assert_eq!(
            transfers + skipped + failed,
            issued,
            "plan '{spec}': {transfers} transfers + {skipped} skipped + {failed} failed \
             != {issued} requests ({report:?})"
        );
        assert_eq!(failed as usize, report.failed.len(), "plan '{spec}'");
        // gauges drain to zero through every failover/retry migration
        let snaps = xfer.lane_snapshots();
        assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "plan '{spec}': {snaps:?}"
        );
        // scripted lane kills are reflected in the health ladder
        if spec.contains("halt") || spec.contains("blackout") {
            assert!(!report.dead_lanes.is_empty(), "plan '{spec}': {report:?}");
            for &l in &report.dead_lanes {
                assert_eq!(xfer.lane_health(l), LaneHealth::Dead, "plan '{spec}'");
            }
        }
    }
}

/// A lane that dies with six transfers in flight: failover re-homes its
/// jobs, the executor drains every expert, and the accumulated output is
/// bit-identical to the fault-free single-lane serial baseline — a
/// recoverable fault must not change a single output bit.
#[test]
fn dead_lane_failover_keeps_output_bits() {
    let experts: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(4, 8, 33);

    let serial_out = {
        let (_s, cache, xfer) =
            fixture(QuantKind::Int4, "rtx4090", 1.0, LaneConfig::default());
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let chaos_out = {
        // lane 1 is 400× slow and dies right after taking its three jobs;
        // the pump re-homes them onto (fast) lane 0 mid-drain
        let lanes = LaneConfig::new(2, LanePolicy::RoundRobin)
            .with_time_scales(vec![0.0, 400.0]);
        let (_s, cache, xfer) = fixture(QuantKind::Int4, "rtx4090", 1.0, lanes);
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 6);
        xfer.halt_lane(1);
        let pool = ThreadPool::new(3);
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        let report = xfer.quiesce().unwrap();
        assert!(report.failovers >= 1, "{report:?}");
        assert_eq!(report.dead_lanes, vec![1]);
        assert!(report.failed.is_empty(), "{report:?}");
        out
    };

    assert_eq!(chaos_out.consumed.len(), 6, "every expert must land");
    assert!(chaos_out.dropped.is_empty(), "{:?}", chaos_out.dropped);
    assert_eq!(
        serial_out.acc.data, chaos_out.acc.data,
        "failover must not change output bits"
    );
}

/// Exhausted retries degrade the plan AdapMoE-gating-style: the failed
/// experts are dropped from the reduction (recorded in the outcome), the
/// survivors still land, and a bit-for-bit replay of the same recorded
/// plan reproduces the exact same degraded output.
#[test]
fn exhausted_retries_drop_experts_and_replay_bit_for_bit() {
    let experts: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(4, 8, 47);

    // baseline: only the three experts that will survive the chaos run
    let survivors_out = {
        let (_s, cache, xfer) =
            fixture(QuantKind::F32, "instant", 0.0, LaneConfig::default());
        for e in 0..3usize {
            xfer.request((0, e), Priority::Prefetch);
        }
        xfer.quiesce().unwrap();
        let plan = build_plan(0, &[0, 1, 2], &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 0, "survivors must be resident");
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    // recorded plan, round-tripped through its wire form as a regression
    // replay would be
    let recorded = FaultPlan::parse("0:flaky:0:1").unwrap();
    let replayed = FaultPlan::parse(&recorded.to_string()).unwrap();
    assert_eq!(recorded, replayed, "fault plans must replay losslessly");

    let degraded = |plan_to_apply: &FaultPlan| {
        // zero retry budget on the only lane: every pending transfer
        // exhausts the ladder and fails terminally
        let lanes = LaneConfig::new(1, LanePolicy::RoundRobin)
            .with_faults(FaultConfig { max_retries: 0, ..FaultConfig::default() });
        let (_s, cache, xfer) = fixture(QuantKind::F32, "instant", 0.0, lanes);
        for e in 0..3usize {
            xfer.request((0, e), Priority::Prefetch);
        }
        xfer.quiesce().unwrap();
        xfer.apply_fault_plan(plan_to_apply, 0);
        for e in 3..6usize {
            xfer.request((0, e), Priority::OnDemand);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        assert_eq!(plan.n_pending(), 3);
        let pool = ThreadPool::new(2);
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        let report = xfer.quiesce().unwrap();
        assert_eq!(report.failed.len(), 3, "{report:?}");
        out
    };

    let run1 = degraded(&recorded);
    let run2 = degraded(&replayed);

    // conservation: consumed + dropped covers the whole plan, dropped
    // experts are exactly the failed transfers
    let mut dropped = run1.dropped.clone();
    dropped.sort_unstable();
    assert_eq!(dropped, vec![3, 4, 5]);
    assert_eq!(run1.consumed.len() + run1.dropped.len(), 6);
    // degraded output equals the survivors-only reduction…
    assert_eq!(
        run1.acc.data, survivors_out.acc.data,
        "dropped experts must contribute exactly nothing"
    );
    // …and the replayed plan reproduces it bit-for-bit
    assert_eq!(run1.acc.data, run2.acc.data, "replay must be bit-for-bit");
    assert_eq!(run1.dropped, run2.dropped);
}

/// Flaky drops with retry budget left are invisible in the output: the
/// re-sent transfers land, nothing is dropped, and the bits match the
/// fault-free serial baseline.
#[test]
fn flaky_lane_retries_are_invisible_in_output_bits() {
    let experts: Vec<usize> = (0..6).collect();
    let (x, coef) = inputs(3, 8, 59);

    let serial_out = {
        let (_s, cache, xfer) =
            fixture(QuantKind::F32, "instant", 0.0, LaneConfig::default());
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        run_layer_serial(&plan, &x, &coef, ScheduleMode::ExpertWise, 4, &cache)
    };

    let chaos_out = {
        let (_s, cache, xfer) = fixture(
            QuantKind::F32,
            "instant",
            0.0,
            LaneConfig::new(2, LanePolicy::RoundRobin),
        );
        // lane 0 drops every job it admits; retries re-home onto lane 1
        xfer.apply_fault_plan(&FaultPlan::parse("0:flaky:0:1").unwrap(), 0);
        for &e in &experts {
            xfer.request((0, e), Priority::Prefetch);
        }
        let plan = build_plan(0, &experts, &[], &cache, &xfer);
        let pool = ThreadPool::new(2);
        let out = run_layer_parallel(
            &plan,
            &x,
            &coef,
            ScheduleMode::ExpertWise,
            4,
            &cache,
            &xfer,
            &pool,
        );
        let report = xfer.quiesce().unwrap();
        assert!(report.retries >= 1, "{report:?}");
        assert!(report.failed.is_empty(), "{report:?}");
        out
    };

    assert_eq!(chaos_out.consumed.len(), 6);
    assert!(chaos_out.dropped.is_empty());
    assert_eq!(serial_out.acc.data, chaos_out.acc.data);
}

/// Property: killing a random lane under a random in-flight mix while
/// three threads hammer the fault pump concurrently never double-lands or
/// loses a transfer — every handle resolves to exactly one of
/// complete/failed, and the counters conserve.
#[test]
fn failover_reissue_is_idempotent_under_concurrent_pumps() {
    prop::check("failover-idempotent", 10, |rng| {
        let n_lanes = 2 + rng.usize_below(3);
        let (_s, _cache, xfer) = fixture(
            QuantKind::F32,
            "instant",
            0.0,
            LaneConfig::new(n_lanes, LanePolicy::RoundRobin),
        );
        let k = 4 + rng.usize_below(9);
        let ids: Vec<(usize, usize)> = (0..k).map(|i| (i % 2, i / 2)).collect();
        let handles: Vec<_> = ids
            .iter()
            .map(|&id| {
                let pri = if rng.chance(0.5) { Priority::OnDemand } else { Priority::Prefetch };
                xfer.request(id, pri)
            })
            .collect();
        let victim = rng.usize_below(n_lanes);
        xfer.halt_lane(victim);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    for _ in 0..50 {
                        xfer.pump_faults();
                        std::thread::sleep(Duration::from_micros(100));
                    }
                });
            }
            xfer.quiesce().unwrap();
        });
        let report = xfer.quiesce().unwrap();
        let transfers = xfer.stats.transfers.load(Ordering::Relaxed);
        let skipped = xfer.stats.skipped_cached.load(Ordering::Relaxed);
        let failed = xfer.stats.failed.load(Ordering::Relaxed);
        prop_assert!(
            transfers + skipped + failed == k as u64,
            "{transfers} transfers + {skipped} skipped + {failed} failed != {k} \
             requests (victim lane {victim}, {report:?})"
        );
        for (h, id) in handles.iter().zip(&ids) {
            prop_assert!(
                h.is_complete() != h.is_failed(),
                "{id:?}: complete={} failed={} — must resolve exactly one way",
                h.is_complete(),
                h.is_failed()
            );
        }
        let snaps = xfer.lane_snapshots();
        prop_assert!(
            snaps.iter().all(|s| s.queued_bytes == 0 && s.queued_jobs == 0),
            "gauges must drain: {snaps:?}"
        );
        Ok(())
    });
}
