//! Integration tests over the real AOT artifacts (run `make artifacts`
//! first; tests skip with a notice when the directory is absent, and fail
//! when ADAPMOE_REQUIRE_ARTIFACTS=1).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use adapmoe::coordinator::engine::{AllocPolicy, Engine, EngineConfig};
use adapmoe::coordinator::gating::GatingPolicy;
use adapmoe::coordinator::policy::{self, RunSettings};
use adapmoe::coordinator::prefetch::PrefetchConfig;
use adapmoe::coordinator::profile::Profile;
use adapmoe::coordinator::scheduler::ScheduleMode;
use adapmoe::memory::platform::Platform;
use adapmoe::memory::quant::QuantKind;
use adapmoe::memory::sharded_cache::Placement;
use adapmoe::memory::transfer::LaneConfig;
use adapmoe::model::config::ModelConfig;
use adapmoe::model::tokenizer::EvalStream;
use adapmoe::model::weights::Weights;
use adapmoe::runtime::{f32_literal, i32_literal, literal_to_tensor, tensor_to_literal, Runtime};
use adapmoe::server::tcp;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else if std::env::var("ADAPMOE_REQUIRE_ARTIFACTS").is_ok() {
        panic!("artifacts missing — run `make artifacts`");
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// Logic-focused settings: real artifacts, instant link, no simulated time.
fn fast_settings(batch: usize, quant: QuantKind) -> RunSettings {
    let mut s = RunSettings::new(batch, 32, quant, Platform::preset("instant").unwrap());
    s.time_scale = 0.0;
    s
}

fn engine(dir: &PathBuf, method: &str, batch: usize, quant: QuantKind) -> Engine {
    let profile = Profile::load(dir).unwrap();
    let ecfg = policy::method(method, &fast_settings(batch, quant), &profile).unwrap();
    Engine::from_artifacts(dir, ecfg).unwrap()
}

#[test]
fn runtime_loads_every_artifact() {
    let Some(dir) = artifacts() else { return };
    let (_cfg, manifest) = ModelConfig::load_manifest(&dir).unwrap();
    let rt = Runtime::load_all(&dir, &manifest).unwrap();
    assert!(rt.names().len() >= 7 * 3, "expected all components × batches");
}

#[test]
fn expert_ffn_artifact_matches_host_reference() {
    let Some(dir) = artifacts() else { return };
    let (cfg, manifest) = ModelConfig::load_manifest(&dir).unwrap();
    let rt = Runtime::load(&dir, &manifest, &["expert_ffn_b1".into()]).unwrap();
    let weights = Weights::load(&dir.join("weights.bin")).unwrap();
    let (w1, w3, w2) = weights.expert(0, 0).unwrap();

    let d = cfg.d_model;
    let x: Vec<f32> = (0..d).map(|i| ((i as f32) / d as f32) - 0.5).collect();
    let coef = [0.75f32];
    let outs = rt
        .run(
            "expert_ffn_b1",
            &[
                &f32_literal(&x, &[1, d]).unwrap(),
                &tensor_to_literal(w1).unwrap(),
                &tensor_to_literal(w3).unwrap(),
                &tensor_to_literal(w2).unwrap(),
                &f32_literal(&coef, &[1]).unwrap(),
            ],
        )
        .unwrap();
    let got = literal_to_tensor(&outs[0]).unwrap();

    // host-side oracle: coef * (silu(x@w1) * (x@w3)) @ w2
    let f = cfg.d_ff;
    let mut h = vec![0f32; f];
    for j in 0..f {
        let (mut a, mut b) = (0f32, 0f32);
        for i in 0..d {
            a += x[i] * w1.data[i * f + j];
            b += x[i] * w3.data[i * f + j];
        }
        let silu = a / (1.0 + (-a).exp());
        h[j] = silu * b;
    }
    for k in 0..d {
        let mut y = 0f32;
        for j in 0..f {
            y += h[j] * w2.data[j * d + k];
        }
        let want = coef[0] * y;
        assert!(
            (got.data[k] - want).abs() < 2e-4,
            "k={k}: {} vs {want}",
            got.data[k]
        );
    }
}

#[test]
fn engine_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<u32> = "let x=".bytes().map(|b| b as u32).collect();
    let mut e1 = engine(&dir, "adapmoe", 1, QuantKind::F32);
    let out1 = e1.generate(&prompt, 12).unwrap();
    let mut e2 = engine(&dir, "adapmoe", 1, QuantKind::F32);
    let out2 = e2.generate(&prompt, 12).unwrap();
    assert_eq!(out1, out2);
    assert_eq!(out1.len(), 12);
}

#[test]
fn offloading_machinery_is_output_transparent() {
    // With top-k gating and F32 experts, every method must produce the
    // byte-identical token stream — caches/prefetch/transfers must never
    // change the math (paper: "identical output consistency").
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<u32> = "the system ".bytes().map(|b| b as u32).collect();
    let mut outs = Vec::new();
    for m in ["baseline", "mixtral-offloading", "pre-gated", "adapmoe-nogate"] {
        let mut e = engine(&dir, m, 1, QuantKind::F32);
        outs.push((m, e.generate(&prompt, 16).unwrap()));
    }
    for w in outs.windows(2) {
        assert_eq!(w[0].1, w[1].1, "{} != {}", w[0].0, w[1].0);
    }
}

#[test]
fn engine_matches_monolithic_dense_reference() {
    // Composed per-component path (F32, top-k) == the single dense_step HLO.
    let Some(dir) = artifacts() else { return };
    let (cfg, manifest) = ModelConfig::load_manifest(&dir).unwrap();
    let rt = Runtime::load(&dir, &manifest, &["dense_step_b1".into()]).unwrap();
    let weights = Weights::load(&dir.join("weights.bin")).unwrap();
    let order: Vec<String> = manifest
        .path("artifacts.dense_step_b1.param_order")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_str().unwrap().to_string())
        .collect();

    let prompt: Vec<u32> = "abc 12".bytes().map(|b| b as u32).collect();

    // engine path
    let mut e = engine(&dir, "mixtral-offloading", 1, QuantKind::F32);
    let row = e.acquire_slot().unwrap();
    let mut engine_logits = Vec::new();
    for &t in &prompt {
        let outs = e.decode_step(&[(row, t)]).unwrap();
        engine_logits.push(outs[0].1.clone());
    }

    // dense reference path
    let (b, h_, s, hd, l) = (1, cfg.n_heads, cfg.max_seq, cfg.head_dim, cfg.n_layers);
    let kv_zero = vec![0f32; l * b * h_ * s * hd];
    let mut kc = f32_literal(&kv_zero, &[l, b, h_, s, hd]).unwrap();
    let mut vc = f32_literal(&kv_zero, &[l, b, h_, s, hd]).unwrap();
    let params: Vec<_> = order
        .iter()
        .map(|name| tensor_to_literal(weights.get(name).unwrap()).unwrap())
        .collect();
    for (pos, &t) in prompt.iter().enumerate() {
        let tok = i32_literal(&[t as i32], &[1]).unwrap();
        let pos_l = i32_literal(&[pos as i32], &[1]).unwrap();
        let mut inputs = vec![&tok, &kc, &vc, &pos_l];
        inputs.extend(params.iter());
        let mut outs = rt.run("dense_step_b1", &inputs).unwrap();
        let logits = literal_to_tensor(&outs[0]).unwrap();
        let want = &engine_logits[pos];
        let got = logits.row(0);
        let max_diff = got
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 5e-3, "pos {pos}: max logit diff {max_diff}");
        kc = outs.remove(1);
        vc = outs.remove(1);
    }
}

#[test]
fn adaptive_gating_reduces_activations_on_eval_stream() {
    let Some(dir) = artifacts() else { return };
    let eval = EvalStream::load(&dir.join("tokens_eval.bin")).unwrap();
    let mut e = engine(&dir, "adapmoe", 1, QuantKind::Int4);
    let row = e.acquire_slot().unwrap();
    for &t in &eval.tokens[..120] {
        e.decode_step(&[(row, t)]).unwrap();
    }
    let ratio = e.trace.mean_single_ratio();
    assert!(
        (0.05..=0.6).contains(&ratio),
        "single-expert ratio {ratio} far from the calibrated 24%"
    );
    // deeper layers should shed experts at least as much as layer 0
    let sr = e.trace.single_ratio();
    let first = sr[0];
    let last = sr[e.cfg.n_layers - 1];
    assert!(last >= first * 0.5, "late layers unexpectedly conservative: {sr:?}");
}

#[test]
fn prefetch_accuracy_is_high_on_eval_stream() {
    let Some(dir) = artifacts() else { return };
    let eval = EvalStream::load(&dir.join("tokens_eval.bin")).unwrap();
    let mut e = engine(&dir, "adapmoe-nogate", 1, QuantKind::Int4);
    let row = e.acquire_slot().unwrap();
    for &t in &eval.tokens[..120] {
        e.decode_step(&[(row, t)]).unwrap();
    }
    let beta = e.trace.beta();
    let mean_beta: f64 = beta.iter().sum::<f64>() / beta.len() as f64;
    assert!(mean_beta > 0.5, "mean prefetch accuracy {mean_beta} too low: {beta:?}");
}

#[test]
fn batched_decode_matches_single_row() {
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<u32> = "expert".bytes().map(|b| b as u32).collect();

    let mut e1 = engine(&dir, "mixtral-offloading", 1, QuantKind::F32);
    let out1 = e1.generate(&prompt, 8).unwrap();

    // batch-4 engine, two identical requests in different rows
    let mut e4 = engine(&dir, "mixtral-offloading", 4, QuantKind::F32);
    let r0 = e4.acquire_slot().unwrap();
    let r1 = e4.acquire_slot().unwrap();
    let mut last = Vec::new();
    for &t in &prompt {
        last = e4.decode_step(&[(r0, t), (r1, t)]).unwrap();
    }
    let mut toks0 = Vec::new();
    let mut toks1 = Vec::new();
    for _ in 0..8 {
        let n0 = adapmoe::model::sampling::greedy(&last.iter().find(|(r, _)| *r == r0).unwrap().1);
        let n1 = adapmoe::model::sampling::greedy(&last.iter().find(|(r, _)| *r == r1).unwrap().1);
        toks0.push(n0);
        toks1.push(n1);
        last = e4.decode_step(&[(r0, n0), (r1, n1)]).unwrap();
    }
    assert_eq!(toks0, out1, "batched row 0 diverged from single-row decode");
    assert_eq!(toks1, out1, "batched row 1 diverged");
}

#[test]
fn server_round_trip() {
    let Some(dir) = artifacts() else { return };
    let addr = "127.0.0.1:17411";
    let shutdown = Arc::new(AtomicBool::new(false));
    let sd = Arc::clone(&shutdown);
    // PJRT handles are not Send: build the engine inside the server thread.
    let server = std::thread::spawn(move || {
        let e = engine(&dir, "adapmoe", 4, QuantKind::Int4);
        tcp::serve(e, addr, sd).unwrap()
    });
    std::thread::sleep(std::time::Duration::from_millis(1500));

    // v1 shape: bare prompt, single completion line
    let (text, _queue_ms, total_ms) = tcp::client_request(addr, "the fast ", 8).unwrap();
    assert_eq!(text.len(), 8, "expected 8 generated bytes, got {:?}", text);
    assert!(total_ms > 0.0);

    // v2: streamed generation over the same server — one line per token,
    // terminated by a done line whose tokens match the streamed count
    let req = adapmoe::server::api::GenerationRequest {
        max_new: 6,
        stream: true,
        ..adapmoe::server::api::GenerationRequest::new("the fast ")
    };
    let done = tcp::client_generate(addr, &req).unwrap();
    assert_eq!(done.tokens.len(), 6);
    assert_eq!(done.token_lines, 6, "token event per generated token");
    assert_eq!(done.finish, "length");

    // stats round-trip reflects both completions
    let stats = tcp::client_stats(addr).unwrap();
    assert_eq!(stats.get("served").and_then(|v| v.as_usize()), Some(2));
    assert!(
        stats.get("tokens_generated").and_then(|v| v.as_usize()).unwrap() >= 14,
        "stats: {stats:?}"
    );

    shutdown.store(true, Ordering::SeqCst);
    let served = server.join().unwrap();
    assert_eq!(served, 2);
}

#[test]
fn dp_allocation_shifts_cache_toward_sensitive_layers() {
    let Some(dir) = artifacts() else { return };
    let profile = Profile::load(&dir).unwrap();
    let l = profile.alpha.len();
    let inputs = adapmoe::coordinator::cache_plan::PlanInputs {
        n_experts: 8,
        budget: 4 * l,
        alpha: profile.alpha.clone(),
        beta: profile.beta.clone(),
    };
    let plan = adapmoe::coordinator::cache_plan::plan(&inputs);
    assert!(plan.allocation.iter().sum::<usize>() <= 4 * l);
    let uniform = vec![4usize; l];
    let dp_cost = plan.expected_loads;
    let uni_cost = adapmoe::coordinator::cache_plan::allocation_cost(&inputs, &uniform);
    assert!(dp_cost <= uni_cost + 1e-12, "DP {dp_cost} worse than uniform {uni_cost}");
}

#[test]
fn tile_wise_engine_matches_expert_wise() {
    let Some(dir) = artifacts() else { return };
    let prompt: Vec<u32> = "cache".bytes().map(|b| b as u32).collect();
    let mk = |mode: ScheduleMode| EngineConfig {
        batch: 1,
        gating: GatingPolicy::TopK { k: 2 },
        prefetch: PrefetchConfig::disabled(),
        alloc: AllocPolicy::Uniform,
        cache_budget: 8, // small cache -> plenty of on-demand (tile) loads
        schedule: mode,
        quant: QuantKind::F32,
        tiers: Vec::new(),
        precision: adapmoe::memory::tiered_store::PrecisionPolicy::Fixed,
        upgrade_budget: 0,
        tier_mode: adapmoe::coordinator::scheduler::TierMode::Degrade,
        platform: Platform::preset("instant").unwrap(),
        n_tiles: 4,
        time_scale: 0.0,
        whole_layer: false,
        compute_workers: 0,
        lanes: LaneConfig::default(),
        devices: 1,
        placement: Placement::LayerSliced,
        fault_plan: None,
        remote: None,
        sensitivity: adapmoe::coordinator::sensitivity::SensitivityPolicy::Uniform,
    };
    let mut ew = Engine::from_artifacts(&dir, mk(ScheduleMode::ExpertWise)).unwrap();
    let mut tw = Engine::from_artifacts(&dir, mk(ScheduleMode::TileWise)).unwrap();
    let a = ew.generate(&prompt, 10).unwrap();
    let b = tw.generate(&prompt, 10).unwrap();
    assert_eq!(a, b, "tile-wise execution changed the output");
}
